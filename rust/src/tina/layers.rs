//! Reference semantics of the four TINA building blocks (paper Eqs. 1-4)
//! on host tensors.  These are the single source of truth the graph
//! interpreter executes; they match `python/compile/kernels/ref.py`
//! exactly (correlation form, valid padding, f32 compute).

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Eq. (1): standard valid 1-D convolution with channels.
///
/// x: (T, Cin, W), k: (Cout, Cin, N), b: (Cout,) -> (T, Cout, W - N + 1)
/// O[t, co, w] = b[co] + sum_ci sum_n x[t, ci, w + n] * k[co, ci, n]
pub fn standard_conv(x: &Tensor, k: &Tensor, b: &Tensor) -> Result<Tensor> {
    if x.rank() != 3 || k.rank() != 3 {
        bail!(
            "standard_conv wants x rank 3 and k rank 3, got {:?} / {:?}",
            x.shape(),
            k.shape()
        );
    }
    let (t, cin, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (cout, cin_k, n) = (k.shape()[0], k.shape()[1], k.shape()[2]);
    if cin != cin_k {
        bail!("channel mismatch: {cin} vs {cin_k}");
    }
    if b.shape() != [cout] {
        bail!("bias shape {:?} != [{cout}]", b.shape());
    }
    if w < n {
        bail!("window {n} longer than input {w}");
    }
    let wout = w - n + 1;
    let mut out = Tensor::zeros(&[t, cout, wout]);
    for ti in 0..t {
        for co in 0..cout {
            let bias = b.data()[co];
            let orow = &mut out.data_mut()[(ti * cout + co) * wout..(ti * cout + co + 1) * wout];
            for ci in 0..cin {
                let xrow = &x.data()[(ti * cin + ci) * w..(ti * cin + ci + 1) * w];
                let krow = &k.data()[(co * cin_k + ci) * n..(co * cin_k + ci + 1) * n];
                for (i, &kv) in krow.iter().enumerate() {
                    if kv == 0.0 {
                        continue;
                    }
                    for (o, &xv) in orow.iter_mut().zip(&xrow[i..i + wout]) {
                        *o += kv * xv;
                    }
                }
            }
            for o in orow.iter_mut() {
                *o += bias;
            }
        }
    }
    Ok(out)
}

/// Eq. (2): depthwise valid 1-D convolution.
///
/// x: (T, C, W), k: (C, M), b: (C,) -> (T, C, W - M + 1)
pub fn depthwise_conv(x: &Tensor, k: &Tensor, b: &Tensor) -> Result<Tensor> {
    if x.rank() != 3 || k.rank() != 2 {
        bail!(
            "depthwise_conv wants x rank 3 and k rank 2, got {:?} / {:?}",
            x.shape(),
            k.shape()
        );
    }
    let (t, c, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (ck, m) = (k.shape()[0], k.shape()[1]);
    if c != ck {
        bail!("channel mismatch: {c} vs {ck}");
    }
    if b.shape() != [c] {
        bail!("bias shape {:?} != [{c}]", b.shape());
    }
    if w < m {
        bail!("window {m} longer than input {w}");
    }
    let wout = w - m + 1;
    let mut out = Tensor::zeros(&[t, c, wout]);
    for ti in 0..t {
        for ci in 0..c {
            let bias = b.data()[ci];
            let xrow = &x.data()[(ti * c + ci) * w..(ti * c + ci) * w + w];
            let krow = &k.data()[ci * m..(ci + 1) * m];
            let orow = &mut out.data_mut()[(ti * c + ci) * wout..(ti * c + ci) * wout + wout];
            for (i, &kv) in krow.iter().enumerate() {
                for (o, &xv) in orow.iter_mut().zip(&xrow[i..i + wout]) {
                    *o += kv * xv;
                }
            }
            for o in orow.iter_mut() {
                *o += bias;
            }
        }
    }
    Ok(out)
}

/// Eq. (3): pointwise (1x1) convolution mixing channels.
///
/// x: (T, Cin, S), k: (Cin, Cout), b: (Cout,) -> (T, Cout, S)
pub fn pointwise_conv(x: &Tensor, k: &Tensor, b: &Tensor) -> Result<Tensor> {
    if x.rank() != 3 || k.rank() != 2 {
        bail!(
            "pointwise_conv wants x rank 3 and k rank 2, got {:?} / {:?}",
            x.shape(),
            k.shape()
        );
    }
    let (t, cin, s) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (cin_k, cout) = (k.shape()[0], k.shape()[1]);
    if cin != cin_k {
        bail!("channel mismatch: {cin} vs {cin_k}");
    }
    if b.shape() != [cout] {
        bail!("bias shape {:?} != [{cout}]", b.shape());
    }
    let mut out = Tensor::zeros(&[t, cout, s]);
    for ti in 0..t {
        for ci in 0..cin {
            let xrow = &x.data()[(ti * cin + ci) * s..(ti * cin + ci + 1) * s];
            for co in 0..cout {
                let kv = k.data()[ci * cout + co];
                if kv == 0.0 {
                    continue;
                }
                let orow = &mut out.data_mut()[(ti * cout + co) * s..(ti * cout + co + 1) * s];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += kv * xv;
                }
            }
        }
        for co in 0..cout {
            let bias = b.data()[co];
            let orow = &mut out.data_mut()[(ti * cout + co) * s..(ti * cout + co + 1) * s];
            for o in orow.iter_mut() {
                *o += bias;
            }
        }
    }
    Ok(out)
}

/// Eq. (4): fully connected layer.
///
/// x: (B, Cin), k: (Cin, Cout), b: (Cout,) -> (B, Cout)
pub fn fully_connected(x: &Tensor, k: &Tensor, b: &Tensor) -> Result<Tensor> {
    if x.rank() != 2 || k.rank() != 2 {
        bail!(
            "fully_connected wants rank-2 x and k, got {:?} / {:?}",
            x.shape(),
            k.shape()
        );
    }
    let mut out = crate::tensor::matmul(x, k)?;
    let (bsz, cout) = (out.shape()[0], out.shape()[1]);
    if b.shape() != [cout] {
        bail!("bias shape {:?} != [{cout}]", b.shape());
    }
    for bi in 0..bsz {
        let orow = &mut out.data_mut()[bi * cout..(bi + 1) * cout];
        for (o, &bv) in orow.iter_mut().zip(b.data()) {
            *o += bv;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_conv_known_values() {
        // x = [1,2,3,4], k = [1,0,-1] (Cout=Cin=1) -> valid corr: [1-3, 2-4]
        let x = Tensor::new(&[1, 1, 4], vec![1., 2., 3., 4.]).unwrap();
        let k = Tensor::new(&[1, 1, 3], vec![1., 0., -1.]).unwrap();
        let b = Tensor::zeros(&[1]);
        let o = standard_conv(&x, &k, &b).unwrap();
        assert_eq!(o.data(), &[-2., -2.]);
    }

    #[test]
    fn standard_conv_channel_mixing() {
        // two input channels, kernel sums them at a single tap
        let x = Tensor::new(&[1, 2, 3], vec![1., 2., 3., 10., 20., 30.]).unwrap();
        let k = Tensor::new(&[1, 2, 1], vec![1., 1.]).unwrap();
        let b = Tensor::new(&[1], vec![0.5]).unwrap();
        let o = standard_conv(&x, &k, &b).unwrap();
        assert_eq!(o.data(), &[11.5, 22.5, 33.5]);
    }

    #[test]
    fn depthwise_keeps_channels_independent() {
        let x = Tensor::new(&[1, 2, 3], vec![1., 2., 3., 10., 20., 30.]).unwrap();
        let k = Tensor::new(&[2, 2], vec![1., 1., 2., 0.]).unwrap();
        let b = Tensor::new(&[2], vec![0., 100.]).unwrap();
        let o = depthwise_conv(&x, &k, &b).unwrap();
        // ch0: [1+2, 2+3]; ch1: [2*10+100, 2*20+100]
        assert_eq!(o.data(), &[3., 5., 120., 140.]);
    }

    #[test]
    fn pointwise_mixes_channels() {
        let x = Tensor::new(&[1, 2, 2], vec![1., 2., 3., 4.]).unwrap();
        let k = Tensor::new(&[2, 1], vec![1., 10.]).unwrap();
        let b = Tensor::new(&[1], vec![0.]).unwrap();
        let o = pointwise_conv(&x, &k, &b).unwrap();
        // O[0,0,s] = x[0,0,s] + 10 x[0,1,s] = [31, 42]
        assert_eq!(o.data(), &[31., 42.]);
    }

    #[test]
    fn fully_connected_with_bias() {
        let x = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let k = Tensor::new(&[2, 1], vec![1., 1.]).unwrap();
        let b = Tensor::new(&[1], vec![-1.]).unwrap();
        let o = fully_connected(&x, &k, &b).unwrap();
        assert_eq!(o.data(), &[2., 6.]);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let x = Tensor::zeros(&[1, 2, 4]);
        let k = Tensor::zeros(&[3, 2]); // wrong channels for depthwise
        let b = Tensor::zeros(&[3]);
        assert!(depthwise_conv(&x, &k, &b).is_err());
        assert!(pointwise_conv(&x, &Tensor::zeros(&[3, 1]), &Tensor::zeros(&[1])).is_err());
        assert!(standard_conv(&x, &Tensor::zeros(&[1, 3, 2]), &Tensor::zeros(&[1])).is_err());
    }

    #[test]
    fn matches_python_ref_semantics_random() {
        // cross-checked against python ref.py in integration tests; here a
        // structural check: depthwise with M=1 is elementwise scaling
        let x = Tensor::randn(&[2, 5, 1], 3);
        let k = Tensor::randn(&[5, 1], 4);
        let b = Tensor::zeros(&[5]);
        let o = depthwise_conv(&x, &k, &b).unwrap();
        for t in 0..2 {
            for c in 0..5 {
                let want = x.at(&[t, c, 0]) * k.at(&[c, 0]);
                assert!((o.at(&[t, c, 0]) - want).abs() < 1e-6);
            }
        }
    }
}
