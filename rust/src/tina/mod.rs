//! The TINA graph: the paper's function -> NN-layer mappings as a small
//! dataflow IR over the four building blocks, plus two executors.
//!
//! This mirrors `python/compile/tina_ops.py` node for node.  It serves
//! four roles:
//!
//! 1. **Specification** — `lower::*` encodes Table 1 in rust, so tests can
//!    assert the mapping structure (which building block carries which
//!    function) independently of jax;
//! 2. **Cross-check** — the interpreter executes the same plans the PJRT
//!    artifacts were lowered from; integration tests compare both outputs;
//! 3. **Fallback serving** — the coordinator's router compiles graphs into
//!    [`exec::ExecPlan`]s and executes them on the planned engine when no
//!    artifact matches a request;
//! 4. **Oracle contract** — the naive [`Interpreter`] stays the reference
//!    the planned engine is validated against: `rust/tests/properties.rs`
//!    asserts **bit-for-bit** plan-vs-interpreter equality on every
//!    `lower::*` graph over randomized shapes (chain fusion only inlines
//!    first operands, which preserves f32 rounding order exactly).  The
//!    one deliberate exception is constant-into-bias folding, which
//!    merges two adds into one and therefore agrees with the oracle to
//!    rounding tolerance, not bitwise — covered by unit tests in
//!    `exec::plan`.
//!
//! # Execution engines
//!
//! [`interp::Interpreter`] is a deliberately naive tree-walker: one fresh
//! heap allocation per node per run, constants cloned every time.  Correct
//! and obvious — the oracle.
//!
//! [`exec`] is the serving engine.  `ExecPlan::compile` runs once per
//! (op, shape signature) and performs:
//!
//! * **constant baking** — weights cloned into the plan once;
//! * **alias analysis** — `Reshape` becomes a metadata-only view;
//! * **fusion** — single-consumer `Add`/`Sub` chains collapse into one
//!   pass, and per-channel-uniform constant adds fold into layer biases;
//! * **plan-level fusion pass** — [`FusionHint::Window`]-tagged window
//!   multiplies fold into their framing producers (standard *or*
//!   depthwise convs, pre-scaled taps), [`FusionHint::Chain`]-tagged ±1
//!   depthwise scales fold onto their producer scale (pre-signed gain
//!   and bias), and batched STFT's merged-axis regrouping copy becomes
//!   a split-view reindex — all bit-for-bit rewrites with verified skip
//!   rules (see `exec`'s module docs);
//! * **liveness analysis** — linear-scan slot assignment recycles each
//!   buffer the moment its last consumer has run (slab [`exec::Arena`]);
//! * **thread fan-out** — kernels split independent batch rows across
//!   `util::threadpool::parallel_for`.
//!
//! The router caches compiled plans keyed by (op, shape signature) and the
//! coordinator reports cache hits/misses in its metrics.

pub mod exec;
pub mod graph;
pub mod interp;
pub mod layers;
pub mod lower;

pub use exec::{Arena, CompileOptions, ExecPlan, LinearProgram, Planned};
pub use graph::{FusionHint, Graph, Node, NodeOp, ValueId};
pub use interp::Interpreter;
