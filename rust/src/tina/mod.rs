//! The TINA graph: the paper's function -> NN-layer mappings as a small
//! dataflow IR over the four building blocks, plus a pure-rust interpreter.
//!
//! This mirrors `python/compile/tina_ops.py` node for node.  It serves
//! three roles:
//!
//! 1. **Specification** — `lower::*` encodes Table 1 in rust, so tests can
//!    assert the mapping structure (which building block carries which
//!    function) independently of jax;
//! 2. **Cross-check** — the interpreter executes the same plans the PJRT
//!    artifacts were lowered from; integration tests compare both outputs;
//! 3. **Fallback** — the coordinator's router executes plans on the
//!    interpreter when no artifact matches a request.

pub mod graph;
pub mod interp;
pub mod layers;
pub mod lower;

pub use graph::{Graph, Node, NodeOp, ValueId};
pub use interp::Interpreter;
