//! Table 1 in rust: lower each signal-processing function to a TINA graph
//! over the four building blocks — the same mappings as
//! `python/compile/tina_ops.py`, §3/§4 of the paper.

use super::exec::fused::{Axis, KernelFamily};
use super::graph::{FusionHint, Graph, NodeOp, ValueId};
use crate::dsp;
use crate::tensor::Tensor;
use anyhow::Result;

// ---------------------------------------------------------------------------
// Oracle reduction-order contract
// ---------------------------------------------------------------------------
//
// The lowering layer owns the numerical contract: every kernel family must
// accumulate its reductions in exactly the order the pure-rust interpreter
// oracle does, or bit-for-bit plan/interpreter equality breaks.  These
// tables are the *source of truth* the static verifier
// (`tina::exec::verify`) checks each kernel implementation's declared
// blocking (`tina::exec::fused::declared_blocking`) against.  They are
// deliberately declared here — away from the kernels — so an implementation
// change cannot silently rewrite its own certificate.

/// The exact per-output-element reduction order (outermost first) the
/// interpreter oracle fixes for a kernel family.  A kernel whose declared
/// reduction order differs fails static verification.
pub fn oracle_reduction_order(f: KernelFamily) -> &'static [Axis] {
    match f {
        // oracle loops input channels outer, taps inner, both ascending
        KernelFamily::StandardConv => &[Axis::Cin, Axis::Tap],
        // per (t, c) element: taps ascending
        KernelFamily::DepthwiseConv => &[Axis::Tap],
        // per (t, co, s) element: input channels ascending
        KernelFamily::PointwiseConv | KernelFamily::PointwiseConvPacked => &[Axis::Cin],
        // per (b, co) element: input features ascending
        KernelFamily::FullyConnected | KernelFamily::FullyConnectedPacked => &[Axis::Cin],
        // pure data movement: no reduction at all
        KernelFamily::Materialize => &[],
        // elementwise chain accumulates terms left to right
        KernelFamily::FusedEw => &[Axis::Term],
    }
}

/// The independent output coordinates of a kernel family — the only axes an
/// implementation may block, tile, or fan across threads.  Blocking any
/// other axis would reassociate a reduction and change f32 rounding.
pub fn oracle_output_axes(f: KernelFamily) -> &'static [Axis] {
    match f {
        KernelFamily::StandardConv => &[Axis::T, Axis::Cout, Axis::Spatial],
        KernelFamily::DepthwiseConv => &[Axis::T, Axis::C, Axis::Spatial],
        KernelFamily::PointwiseConv | KernelFamily::PointwiseConvPacked => {
            &[Axis::T, Axis::Cout, Axis::Spatial]
        }
        KernelFamily::FullyConnected | KernelFamily::FullyConnectedPacked => {
            &[Axis::T, Axis::Cout]
        }
        KernelFamily::Materialize | KernelFamily::FusedEw => &[Axis::Elem],
    }
}

/// §3.1: elementwise (H, W) multiply via depthwise conv with C = H*W.
pub fn ewmult(h: usize, w: usize) -> Graph {
    let mut g = Graph::new();
    let c = h * w;
    let a = g.input(&[h, w]);
    let b = g.input(&[h, w]);
    let x = g.push(NodeOp::Reshape(vec![1, c, 1]), &[a]);
    let k = g.push(NodeOp::Reshape(vec![c, 1]), &[b]);
    let bias = g.constant(Tensor::zeros(&[c]));
    let o = g.push(NodeOp::DepthwiseConv1d, &[x, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![h, w]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// §3.3: elementwise add — ones kernel, second operand through the bias.
pub fn ewadd(h: usize, w: usize) -> Graph {
    let mut g = Graph::new();
    let c = h * w;
    let a = g.input(&[h, w]);
    let b = g.input(&[h, w]);
    let x = g.push(NodeOp::Reshape(vec![1, c, 1]), &[a]);
    let k = g.constant(Tensor::ones(&[c, 1]));
    let bias = g.push(NodeOp::Reshape(vec![c]), &[b]);
    let o = g.push(NodeOp::DepthwiseConv1d, &[x, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![h, w]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// §3.2: (M, L) x (L, N) matmul via pointwise conv (channels = L).
pub fn matmul(m: usize, l: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input(&[m, l]);
    let y = g.input(&[l, n]);
    // rows ride the batch (T) dimension: (M, L, 1) channels-as-contraction
    let xi = g.push(NodeOp::Reshape(vec![m, l, 1]), &[x]);
    let bias = g.constant(Tensor::zeros(&[n]));
    let o = g.push(NodeOp::PointwiseConv, &[xi, y, bias]); // (M, N, 1)
    let o = g.push(NodeOp::Reshape(vec![m, n]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// §3.4: summation of a length-L vector via a ones-kernel FC layer.
pub fn summation(l: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input(&[l]);
    let xi = g.push(NodeOp::Reshape(vec![1, l]), &[x]);
    let k = g.constant(Tensor::ones(&[l, 1]));
    let bias = g.constant(Tensor::zeros(&[1]));
    let o = g.push(NodeOp::FullyConnected, &[xi, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![1]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// Shared: (B, L) x real (L, N) kernel via one pointwise conv, batch on T.
fn real_pointwise(g: &mut Graph, x: ValueId, b_: usize, l: usize, k: ValueId, n: usize, bias: ValueId) -> ValueId {
    let xi = g.push(NodeOp::Reshape(vec![b_, l, 1]), &[x]);
    let o = g.push(NodeOp::PointwiseConv, &[xi, k, bias]); // (B, N, 1)
    g.push(NodeOp::Reshape(vec![b_, n]), &[o])
}

/// Shared: (B, L) x complex (L, N) kernel via four pointwise convs.
/// Returns (re, im) value ids.
fn complex_pointwise(
    g: &mut Graph,
    x_re: ValueId,
    x_im: ValueId,
    b_: usize,
    l: usize,
    k_re: Tensor,
    k_im: Tensor,
) -> (ValueId, ValueId) {
    let n = k_re.shape()[1];
    let bias = g.constant(Tensor::zeros(&[n]));
    let kre = g.constant(k_re);
    let kim = g.constant(k_im);

    let rr = real_pointwise(g, x_re, b_, l, kre, n, bias);
    let ri = real_pointwise(g, x_re, b_, l, kim, n, bias);
    let ir = real_pointwise(g, x_im, b_, l, kre, n, bias);
    let ii = real_pointwise(g, x_im, b_, l, kim, n, bias);

    let out_re = g.push(NodeOp::Sub, &[rr, ii]); // (B, N)
    let out_im = g.push(NodeOp::Add, &[ri, ir]);
    (out_re, out_im)
}

/// §4.1: DFT of a real (B, N) signal — pointwise conv with the DFM.
/// The imaginary input branch is skipped entirely (real signal), matching
/// python/compile/tina_ops.py.
pub fn dft(b: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input(&[b, n]);
    let (f_re, f_im) = dsp::dft_matrix(n);
    let bias = g.constant(Tensor::zeros(&[n]));
    let kre = g.constant(f_re);
    let kim = g.constant(f_im);
    let o_re = real_pointwise(&mut g, x, b, n, kre, n, bias);
    let o_im = real_pointwise(&mut g, x, b, n, kim, n, bias);
    g.set_outputs(&[o_re, o_im]);
    g
}

/// §4.2: IDFT of a complex (B, N) spectrum — pointwise conv with the IDFM.
pub fn idft(b: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let x_re = g.input(&[b, n]);
    let x_im = g.input(&[b, n]);
    let (if_re, if_im) = dsp::idft_matrix(n);
    let (o_re, o_im) = complex_pointwise(&mut g, x_re, x_im, b, n, if_re, if_im);
    g.set_outputs(&[o_re, o_im]);
    g
}

/// §4.3: FIR filter via standard conv, kernel = reversed taps.
pub fn fir(b: usize, l: usize, taps: &[f32]) -> Result<Graph> {
    let m = taps.len();
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let rev: Vec<f32> = taps.iter().rev().copied().collect();
    let k = g.constant(Tensor::new(&[1, 1, m], rev)?);
    let bias = g.constant(Tensor::zeros(&[1]));
    let o = g.push(NodeOp::StandardConv1d, &[xi, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![b, l - m + 1]), &[o]);
    g.set_outputs(&[o]);
    Ok(g)
}

/// §4.4: unfolding via standard conv with an identity kernel.
pub fn unfold(b: usize, l: usize, window: usize) -> Result<Graph> {
    let j = window;
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let eye = Tensor::eye(j).reshape(&[j, 1, j])?;
    let k = g.constant(eye);
    let bias = g.constant(Tensor::zeros(&[j]));
    let o = g.push(NodeOp::StandardConv1d, &[xi, k, bias]); // (B, J, Wout)
    let o = g.push(NodeOp::Permute3([0, 2, 1]), &[o]); // (B, Wout, J)
    g.set_outputs(&[o]);
    Ok(g)
}

/// Extension op (paper future work): short-time Fourier transform from
/// three Table-1 building blocks — framing via strided standard conv
/// (identity kernel, §4.4 + §2.1's stride), Hamming windowing via
/// depthwise conv (§3.1), DFT via pointwise conv (§4.1).
///
/// x: (B, L) -> (re, im) each (B, F, nfft), F = (L - nfft)/hop + 1.
/// Mirrors python/compile/tina_ops.py::stft.
pub fn stft(b: usize, l: usize, nfft: usize, hop: usize) -> Result<Graph> {
    if l < nfft {
        anyhow::bail!("signal {l} shorter than one {nfft}-sample frame");
    }
    let frames = (l - nfft) / hop + 1;
    let mut g = Graph::new();
    let x = g.input(&[b, l]);

    // 1. framing: unfold then stride the frame axis
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let eye = Tensor::eye(nfft).reshape(&[nfft, 1, nfft])?;
    let k = g.constant(eye);
    let bias0 = g.constant(Tensor::zeros(&[nfft]));
    let unfolded = g.push(NodeOp::StandardConv1d, &[xi, k, bias0]); // (B, nfft, L-nfft+1)
    let framed = g.push(
        NodeOp::StridedSlice {
            axis: 2,
            stride: hop,
            count: frames,
        },
        &[unfolded],
    ); // (B, nfft, F)
    let framed = g.push(NodeOp::Permute3([0, 2, 1]), &[framed]); // (B, F, nfft)
    let rows = g.push(NodeOp::Reshape(vec![b * frames, nfft, 1]), &[framed]);

    // 2. windowing: depthwise conv, channels = sample-in-frame, M = 1.
    // Tagged `FusionHint::Window`: the planner may fold this elementwise
    // multiply into the framing conv above by pre-scaling its identity
    // taps (the plan-level window fold; the hint is advisory — the pass
    // re-proves one-hot unit taps, zero conv bias and sole-consumer
    // structure before rewriting anything).
    let win: Vec<f32> = crate::dsp::hamming(nfft).iter().map(|&v| v as f32).collect();
    let kwin = g.constant(Tensor::new(&[nfft, 1], win)?);
    let bias_w = g.constant(Tensor::zeros(&[nfft]));
    let xw = g.push_with_hint(
        NodeOp::DepthwiseConv1d,
        &[rows, kwin, bias_w],
        FusionHint::Window,
    ); // (B*F, nfft, 1)
    let xw = g.push(NodeOp::Reshape(vec![b * frames, nfft]), &[xw]);

    // 3. DFT across frame samples
    let (f_re, f_im) = dsp::dft_matrix(nfft);
    let bias_d = g.constant(Tensor::zeros(&[nfft]));
    let kre = g.constant(f_re);
    let kim = g.constant(f_im);
    let o_re = real_pointwise(&mut g, xw, b * frames, nfft, kre, nfft, bias_d);
    let o_im = real_pointwise(&mut g, xw, b * frames, nfft, kim, nfft, bias_d);
    let o_re = g.push(NodeOp::Reshape(vec![b, frames, nfft]), &[o_re]);
    let o_im = g.push(NodeOp::Reshape(vec![b, frames, nfft]), &[o_im]);
    g.set_outputs(&[o_re, o_im]);
    Ok(g)
}

/// §5.2 Eq. 20: the polyphase FIR bank as one depthwise conv.
/// Appends to an existing graph and returns the (B, P, Ns') value.
fn pfb_fir_nodes(
    g: &mut Graph,
    x: ValueId,
    b: usize,
    l: usize,
    cfg: dsp::PfbConfig,
) -> Result<ValueId> {
    let (p, m) = (cfg.branches, cfg.taps_per_branch);
    let nspec = l / p;
    cfg.output_spectra(l)?; // validates divisibility and length
    let xp = g.push(NodeOp::Reshape(vec![b, nspec, p]), &[x]);
    let xp = g.push(NodeOp::Permute3([0, 2, 1]), &[xp]); // (B, P, Nspec)
    // correlation kernel = per-branch reversed taps
    let bank = cfg.bank()?; // (P, M) row-major
    let mut rev = vec![0.0f32; p * m];
    for pi in 0..p {
        for t in 0..m {
            rev[pi * m + t] = bank[pi * m + (m - 1 - t)];
        }
    }
    let k = g.constant(Tensor::new(&[p, m], rev)?);
    let bias = g.constant(Tensor::zeros(&[p]));
    Ok(g.push(NodeOp::DepthwiseConv1d, &[xp, k, bias]))
}

/// Fig. 3 left: subfiltered signals only.
pub fn pfb_fir(b: usize, l: usize, cfg: dsp::PfbConfig) -> Result<Graph> {
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let o = pfb_fir_nodes(&mut g, x, b, l, cfg)?;
    g.set_outputs(&[o]);
    Ok(g)
}

/// Fig. 3 right: full PFB — FIR bank + DFT across branches
/// (depthwise conv -> pointwise conv with the DFM kernel).
pub fn pfb(b: usize, l: usize, cfg: dsp::PfbConfig) -> Result<Graph> {
    let p = cfg.branches;
    let ns = cfg.output_spectra(l)?;
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let y = pfb_fir_nodes(&mut g, x, b, l, cfg)?; // (B, P, Ns)
    let (f_re, f_im) = dsp::dft_matrix(p);
    let bias = g.constant(Tensor::zeros(&[p]));
    let kre = g.constant(f_re);
    let kim = g.constant(f_im);
    let o_re = g.push(NodeOp::PointwiseConv, &[y, kre, bias]); // (B, P, Ns)
    let o_im = g.push(NodeOp::PointwiseConv, &[y, kim, bias]);
    let o_re = g.push(NodeOp::Permute3([0, 2, 1]), &[o_re]); // (B, Ns, P)
    let o_im = g.push(NodeOp::Permute3([0, 2, 1]), &[o_im]);
    g.set_outputs(&[o_re, o_im]);
    let _ = ns;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_building_blocks() {
        // The paper's Table 1, asserted structurally.
        assert_eq!(ewmult(4, 4).layer_names(), vec!["depthwise_conv1d"]);
        assert_eq!(ewadd(4, 4).layer_names(), vec!["depthwise_conv1d"]);
        assert_eq!(matmul(4, 5, 6).layer_names(), vec!["pointwise_conv"]);
        assert_eq!(summation(16).layer_names(), vec!["fully_connected"]);
        assert_eq!(
            dft(2, 8).layer_names(),
            vec!["pointwise_conv"; 2],
            "DFT of a real signal = pointwise conv (re + im kernels)"
        );
        assert_eq!(
            idft(2, 8).layer_names(),
            vec!["pointwise_conv"; 4],
            "IDFT of a complex spectrum = 4 pointwise convs"
        );
        assert_eq!(
            fir(1, 64, &[1.0; 8]).unwrap().layer_names(),
            vec!["standard_conv1d"]
        );
        assert_eq!(
            unfold(1, 64, 8).unwrap().layer_names(),
            vec!["standard_conv1d"]
        );
        let cfg = dsp::PfbConfig::new(8, 4);
        assert_eq!(
            pfb_fir(1, 64, cfg).unwrap().layer_names(),
            vec!["depthwise_conv1d"]
        );
        assert_eq!(
            pfb(1, 64, cfg).unwrap().layer_names(),
            vec!["depthwise_conv1d", "pointwise_conv", "pointwise_conv"]
        );
    }

    #[test]
    fn all_lowerings_validate() {
        ewmult(3, 7).validate().unwrap();
        ewadd(5, 2).validate().unwrap();
        matmul(3, 4, 5).validate().unwrap();
        summation(100).validate().unwrap();
        dft(2, 16).validate().unwrap();
        idft(2, 16).validate().unwrap();
        fir(2, 128, &[0.5; 16]).unwrap().validate().unwrap();
        unfold(2, 128, 8).unwrap().validate().unwrap();
        let cfg = dsp::PfbConfig::new(8, 4);
        pfb_fir(2, 8 * 32, cfg).unwrap().validate().unwrap();
        pfb(2, 8 * 32, cfg).unwrap().validate().unwrap();
    }

    #[test]
    fn output_shapes() {
        let shapes = matmul(3, 4, 5).infer_shapes().unwrap();
        let g = matmul(3, 4, 5);
        assert_eq!(shapes[g.outputs[0].0], vec![3, 5]);

        let g = unfold(2, 100, 8).unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![2, 93, 8]);

        let cfg = dsp::PfbConfig::new(8, 4);
        let g = pfb(1, 8 * 32, cfg).unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![1, 29, 8]);
        assert_eq!(shapes[g.outputs[1].0], vec![1, 29, 8]);
    }

    #[test]
    fn stft_uses_three_building_blocks() {
        let g = stft(1, 1024, 256, 128).unwrap();
        assert_eq!(
            g.layer_names(),
            vec![
                "standard_conv1d", // framing (unfold)
                "depthwise_conv1d", // windowing
                "pointwise_conv",  // DFT re
                "pointwise_conv",  // DFT im
            ]
        );
        g.validate().unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![1, 7, 256]);
    }

    #[test]
    fn stft_rejects_short_signal() {
        assert!(stft(1, 100, 256, 128).is_err());
    }

    #[test]
    fn pfb_rejects_bad_lengths() {
        let cfg = dsp::PfbConfig::new(8, 4);
        assert!(pfb_fir(1, 65, cfg).is_err()); // not divisible by P
        assert!(pfb_fir(1, 16, cfg).is_err()); // too short
    }
}
