//! Table 1 in rust: lower each signal-processing function to a TINA graph
//! over the four building blocks — the same mappings as
//! `python/compile/tina_ops.py`, §3/§4 of the paper.

use super::exec::fused::{Axis, KernelFamily};
use super::graph::{FusionHint, Graph, NodeOp, ValueId};
use crate::dsp;
use crate::tensor::Tensor;
use anyhow::Result;

// ---------------------------------------------------------------------------
// Oracle reduction-order contract
// ---------------------------------------------------------------------------
//
// The lowering layer owns the numerical contract: every kernel family must
// accumulate its reductions in exactly the order the pure-rust interpreter
// oracle does, or bit-for-bit plan/interpreter equality breaks.  These
// tables are the *source of truth* the static verifier
// (`tina::exec::verify`) checks each kernel implementation's declared
// blocking (`tina::exec::fused::declared_blocking`) against.  They are
// deliberately declared here — away from the kernels — so an implementation
// change cannot silently rewrite its own certificate.

/// The exact per-output-element reduction order (outermost first) the
/// interpreter oracle fixes for a kernel family.  A kernel whose declared
/// reduction order differs fails static verification.
pub fn oracle_reduction_order(f: KernelFamily) -> &'static [Axis] {
    match f {
        // oracle loops input channels outer, taps inner, both ascending
        KernelFamily::StandardConv => &[Axis::Cin, Axis::Tap],
        // per (t, c) element: taps ascending
        KernelFamily::DepthwiseConv => &[Axis::Tap],
        // per (t, co, s) element: input channels ascending
        KernelFamily::PointwiseConv | KernelFamily::PointwiseConvPacked => &[Axis::Cin],
        // per (b, co) element: input features ascending
        KernelFamily::FullyConnected | KernelFamily::FullyConnectedPacked => &[Axis::Cin],
        // pure data movement: no reduction at all
        KernelFamily::Materialize => &[],
        // elementwise chain accumulates terms left to right
        KernelFamily::FusedEw => &[Axis::Term],
    }
}

/// The independent output coordinates of a kernel family — the only axes an
/// implementation may block, tile, or fan across threads.  Blocking any
/// other axis would reassociate a reduction and change f32 rounding.
pub fn oracle_output_axes(f: KernelFamily) -> &'static [Axis] {
    match f {
        KernelFamily::StandardConv => &[Axis::T, Axis::Cout, Axis::Spatial],
        KernelFamily::DepthwiseConv => &[Axis::T, Axis::C, Axis::Spatial],
        KernelFamily::PointwiseConv | KernelFamily::PointwiseConvPacked => {
            &[Axis::T, Axis::Cout, Axis::Spatial]
        }
        KernelFamily::FullyConnected | KernelFamily::FullyConnectedPacked => {
            &[Axis::T, Axis::Cout]
        }
        KernelFamily::Materialize | KernelFamily::FusedEw => &[Axis::Elem],
    }
}

/// §3.1: elementwise (H, W) multiply via depthwise conv with C = H*W.
pub fn ewmult(h: usize, w: usize) -> Graph {
    let mut g = Graph::new();
    let c = h * w;
    let a = g.input(&[h, w]);
    let b = g.input(&[h, w]);
    let x = g.push(NodeOp::Reshape(vec![1, c, 1]), &[a]);
    let k = g.push(NodeOp::Reshape(vec![c, 1]), &[b]);
    let bias = g.constant(Tensor::zeros(&[c]));
    let o = g.push(NodeOp::DepthwiseConv1d, &[x, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![h, w]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// §3.3: elementwise add — ones kernel, second operand through the bias.
pub fn ewadd(h: usize, w: usize) -> Graph {
    let mut g = Graph::new();
    let c = h * w;
    let a = g.input(&[h, w]);
    let b = g.input(&[h, w]);
    let x = g.push(NodeOp::Reshape(vec![1, c, 1]), &[a]);
    let k = g.constant(Tensor::ones(&[c, 1]));
    let bias = g.push(NodeOp::Reshape(vec![c]), &[b]);
    let o = g.push(NodeOp::DepthwiseConv1d, &[x, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![h, w]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// §3.2: (M, L) x (L, N) matmul via pointwise conv (channels = L).
pub fn matmul(m: usize, l: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input(&[m, l]);
    let y = g.input(&[l, n]);
    // rows ride the batch (T) dimension: (M, L, 1) channels-as-contraction
    let xi = g.push(NodeOp::Reshape(vec![m, l, 1]), &[x]);
    let bias = g.constant(Tensor::zeros(&[n]));
    let o = g.push(NodeOp::PointwiseConv, &[xi, y, bias]); // (M, N, 1)
    let o = g.push(NodeOp::Reshape(vec![m, n]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// §3.4: summation of a length-L vector via a ones-kernel FC layer.
pub fn summation(l: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input(&[l]);
    let xi = g.push(NodeOp::Reshape(vec![1, l]), &[x]);
    let k = g.constant(Tensor::ones(&[l, 1]));
    let bias = g.constant(Tensor::zeros(&[1]));
    let o = g.push(NodeOp::FullyConnected, &[xi, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![1]), &[o]);
    g.set_outputs(&[o]);
    g
}

/// Shared: (B, L) x real (L, N) kernel via one pointwise conv, batch on T.
fn real_pointwise(g: &mut Graph, x: ValueId, b_: usize, l: usize, k: ValueId, n: usize, bias: ValueId) -> ValueId {
    let xi = g.push(NodeOp::Reshape(vec![b_, l, 1]), &[x]);
    let o = g.push(NodeOp::PointwiseConv, &[xi, k, bias]); // (B, N, 1)
    g.push(NodeOp::Reshape(vec![b_, n]), &[o])
}

/// Shared: (B, L) x complex (L, N) kernel via four pointwise convs.
/// Returns (re, im) value ids.
fn complex_pointwise(
    g: &mut Graph,
    x_re: ValueId,
    x_im: ValueId,
    b_: usize,
    l: usize,
    k_re: Tensor,
    k_im: Tensor,
) -> (ValueId, ValueId) {
    let n = k_re.shape()[1];
    let bias = g.constant(Tensor::zeros(&[n]));
    let kre = g.constant(k_re);
    let kim = g.constant(k_im);

    let rr = real_pointwise(g, x_re, b_, l, kre, n, bias);
    let ri = real_pointwise(g, x_re, b_, l, kim, n, bias);
    let ir = real_pointwise(g, x_im, b_, l, kre, n, bias);
    let ii = real_pointwise(g, x_im, b_, l, kim, n, bias);

    let out_re = g.push(NodeOp::Sub, &[rr, ii]); // (B, N)
    let out_im = g.push(NodeOp::Add, &[ri, ir]);
    (out_re, out_im)
}

/// §4.1: DFT of a real (B, N) signal — pointwise conv with the DFM.
/// The imaginary input branch is skipped entirely (real signal), matching
/// python/compile/tina_ops.py.
pub fn dft(b: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input(&[b, n]);
    let (f_re, f_im) = dsp::dft_matrix(n);
    let bias = g.constant(Tensor::zeros(&[n]));
    let kre = g.constant(f_re);
    let kim = g.constant(f_im);
    let o_re = real_pointwise(&mut g, x, b, n, kre, n, bias);
    let o_im = real_pointwise(&mut g, x, b, n, kim, n, bias);
    g.set_outputs(&[o_re, o_im]);
    g
}

/// §4.2: IDFT of a complex (B, N) spectrum — pointwise conv with the IDFM.
pub fn idft(b: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let x_re = g.input(&[b, n]);
    let x_im = g.input(&[b, n]);
    let (if_re, if_im) = dsp::idft_matrix(n);
    let (o_re, o_im) = complex_pointwise(&mut g, x_re, x_im, b, n, if_re, if_im);
    g.set_outputs(&[o_re, o_im]);
    g
}

/// §4.3: FIR filter via standard conv, kernel = reversed taps.
pub fn fir(b: usize, l: usize, taps: &[f32]) -> Result<Graph> {
    let m = taps.len();
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let rev: Vec<f32> = taps.iter().rev().copied().collect();
    let k = g.constant(Tensor::new(&[1, 1, m], rev)?);
    let bias = g.constant(Tensor::zeros(&[1]));
    let o = g.push(NodeOp::StandardConv1d, &[xi, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![b, l - m + 1]), &[o]);
    g.set_outputs(&[o]);
    Ok(g)
}

/// §4.4: unfolding via standard conv with an identity kernel.
pub fn unfold(b: usize, l: usize, window: usize) -> Result<Graph> {
    let j = window;
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let eye = Tensor::eye(j).reshape(&[j, 1, j])?;
    let k = g.constant(eye);
    let bias = g.constant(Tensor::zeros(&[j]));
    let o = g.push(NodeOp::StandardConv1d, &[xi, k, bias]); // (B, J, Wout)
    let o = g.push(NodeOp::Permute3([0, 2, 1]), &[o]); // (B, Wout, J)
    g.set_outputs(&[o]);
    Ok(g)
}

/// Shared: the full STFT pipeline appended to an existing graph.
/// Returns the spectra `(re, im)` at the flattened `(B*F, nfft)` row
/// level plus the frame count `F` — callers that want the public
/// `(B, F, nfft)` layout add the final reshapes themselves (the
/// FX correlator keeps working at the row level).
fn stft_nodes(
    g: &mut Graph,
    x: ValueId,
    b: usize,
    l: usize,
    nfft: usize,
    hop: usize,
) -> Result<(ValueId, ValueId, usize)> {
    if l < nfft {
        anyhow::bail!("signal {l} shorter than one {nfft}-sample frame");
    }
    let frames = (l - nfft) / hop + 1;

    // 1. framing: unfold then stride the frame axis
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let eye = Tensor::eye(nfft).reshape(&[nfft, 1, nfft])?;
    let k = g.constant(eye);
    let bias0 = g.constant(Tensor::zeros(&[nfft]));
    let unfolded = g.push(NodeOp::StandardConv1d, &[xi, k, bias0]); // (B, nfft, L-nfft+1)
    let framed = g.push(
        NodeOp::StridedSlice {
            axis: 2,
            stride: hop,
            count: frames,
        },
        &[unfolded],
    ); // (B, nfft, F)
    let framed = g.push(NodeOp::Permute3([0, 2, 1]), &[framed]); // (B, F, nfft)
    let rows = g.push(NodeOp::Reshape(vec![b * frames, nfft, 1]), &[framed]);

    // 2. windowing: depthwise conv, channels = sample-in-frame, M = 1.
    // Tagged `FusionHint::Window`: the planner may fold this elementwise
    // multiply into the framing conv above by pre-scaling its identity
    // taps (the plan-level window fold; the hint is advisory — the pass
    // re-proves one-hot unit taps, zero conv bias and sole-consumer
    // structure before rewriting anything).
    let win: Vec<f32> = crate::dsp::hamming(nfft).iter().map(|&v| v as f32).collect();
    let kwin = g.constant(Tensor::new(&[nfft, 1], win)?);
    let bias_w = g.constant(Tensor::zeros(&[nfft]));
    let xw = g.push_with_hint(
        NodeOp::DepthwiseConv1d,
        &[rows, kwin, bias_w],
        FusionHint::Window,
    ); // (B*F, nfft, 1)
    let xw = g.push(NodeOp::Reshape(vec![b * frames, nfft]), &[xw]);

    // 3. DFT across frame samples
    let (f_re, f_im) = dsp::dft_matrix(nfft);
    let bias_d = g.constant(Tensor::zeros(&[nfft]));
    let kre = g.constant(f_re);
    let kim = g.constant(f_im);
    let o_re = real_pointwise(g, xw, b * frames, nfft, kre, nfft, bias_d);
    let o_im = real_pointwise(g, xw, b * frames, nfft, kim, nfft, bias_d);
    Ok((o_re, o_im, frames))
}

/// Extension op (paper future work): short-time Fourier transform from
/// three Table-1 building blocks — framing via strided standard conv
/// (identity kernel, §4.4 + §2.1's stride), Hamming windowing via
/// depthwise conv (§3.1), DFT via pointwise conv (§4.1).
///
/// x: (B, L) -> (re, im) each (B, F, nfft), F = (L - nfft)/hop + 1.
/// Mirrors python/compile/tina_ops.py::stft.
pub fn stft(b: usize, l: usize, nfft: usize, hop: usize) -> Result<Graph> {
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let (o_re, o_im, frames) = stft_nodes(&mut g, x, b, l, nfft, hop)?;
    let o_re = g.push(NodeOp::Reshape(vec![b, frames, nfft]), &[o_re]);
    let o_im = g.push(NodeOp::Reshape(vec![b, frames, nfft]), &[o_im]);
    g.set_outputs(&[o_re, o_im]);
    Ok(g)
}

/// §5.2 Eq. 20: the polyphase FIR bank as one depthwise conv.
/// Appends to an existing graph and returns the (B, P, Ns') value.
fn pfb_fir_nodes(
    g: &mut Graph,
    x: ValueId,
    b: usize,
    l: usize,
    cfg: dsp::PfbConfig,
) -> Result<ValueId> {
    let (p, m) = (cfg.branches, cfg.taps_per_branch);
    let nspec = l / p;
    cfg.output_spectra(l)?; // validates divisibility and length
    let xp = g.push(NodeOp::Reshape(vec![b, nspec, p]), &[x]);
    let xp = g.push(NodeOp::Permute3([0, 2, 1]), &[xp]); // (B, P, Nspec)
    // correlation kernel = per-branch reversed taps
    let bank = cfg.bank()?; // (P, M) row-major
    let mut rev = vec![0.0f32; p * m];
    for pi in 0..p {
        for t in 0..m {
            rev[pi * m + t] = bank[pi * m + (m - 1 - t)];
        }
    }
    let k = g.constant(Tensor::new(&[p, m], rev)?);
    let bias = g.constant(Tensor::zeros(&[p]));
    Ok(g.push(NodeOp::DepthwiseConv1d, &[xp, k, bias]))
}

/// Fig. 3 left: subfiltered signals only.
pub fn pfb_fir(b: usize, l: usize, cfg: dsp::PfbConfig) -> Result<Graph> {
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let o = pfb_fir_nodes(&mut g, x, b, l, cfg)?;
    g.set_outputs(&[o]);
    Ok(g)
}

/// Fig. 3 right: full PFB — FIR bank + DFT across branches
/// (depthwise conv -> pointwise conv with the DFM kernel).
pub fn pfb(b: usize, l: usize, cfg: dsp::PfbConfig) -> Result<Graph> {
    let p = cfg.branches;
    let ns = cfg.output_spectra(l)?;
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let y = pfb_fir_nodes(&mut g, x, b, l, cfg)?; // (B, P, Ns)
    let (f_re, f_im) = dsp::dft_matrix(p);
    let bias = g.constant(Tensor::zeros(&[p]));
    let kre = g.constant(f_re);
    let kim = g.constant(f_im);
    let o_re = g.push(NodeOp::PointwiseConv, &[y, kre, bias]); // (B, P, Ns)
    let o_im = g.push(NodeOp::PointwiseConv, &[y, kim, bias]);
    let o_re = g.push(NodeOp::Permute3([0, 2, 1]), &[o_re]); // (B, Ns, P)
    let o_im = g.push(NodeOp::Permute3([0, 2, 1]), &[o_im]);
    g.set_outputs(&[o_re, o_im]);
    let _ = ns;
    Ok(g)
}

// ---------------------------------------------------------------------------
// Complex-valued primitives (split re/im channels)
// ---------------------------------------------------------------------------

/// Shared: elementwise product of two already-defined values each holding
/// `q` elements, via a depthwise conv (§3.1) — activation `(1, q, 1)`,
/// kernel `(q, 1)`, zero bias.  Returns a `(1, q, 1)` value.
fn ew_product_nodes(g: &mut Graph, act: ValueId, ker: ValueId, q: usize) -> ValueId {
    let a = g.push(NodeOp::Reshape(vec![1, q, 1]), &[act]);
    let k = g.push(NodeOp::Reshape(vec![q, 1]), &[ker]);
    let bias = g.constant(Tensor::zeros(&[q]));
    g.push(NodeOp::DepthwiseConv1d, &[a, k, bias])
}

/// Shared: complex multiply of two already-defined value pairs, each
/// holding `q` flattened elements.  Same sign convention as
/// [`complex_pointwise`]: `re = rr - ii`, `im = ri + ir`.  The `a`
/// side rides the activation slot of each product, the `b` side the
/// kernel slot.  Returns `(re, im)` values shaped `(1, q, 1)`.
fn complex_mul_nodes(
    g: &mut Graph,
    a_re: ValueId,
    a_im: ValueId,
    b_re: ValueId,
    b_im: ValueId,
    q: usize,
) -> (ValueId, ValueId) {
    let rr = ew_product_nodes(g, a_re, b_re, q);
    let ii = ew_product_nodes(g, a_im, b_im, q);
    let ri = ew_product_nodes(g, a_im, b_re, q);
    let ir = ew_product_nodes(g, a_re, b_im, q);
    let re = g.push(NodeOp::Sub, &[rr, ii]);
    let im = g.push(NodeOp::Add, &[ri, ir]);
    (re, im)
}

/// Elementwise complex multiply of two `(B, N)` complex pairs carried as
/// split re/im channels — four depthwise products (§3.1) plus one
/// add/sub pair.  Inputs in order `a_re, a_im, b_re, b_im`; outputs
/// `(re, im) = a · b`, each `(B, N)`.
pub fn complex_mul(b: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let q = b * n;
    let a_re = g.input(&[b, n]);
    let a_im = g.input(&[b, n]);
    let b_re = g.input(&[b, n]);
    let b_im = g.input(&[b, n]);
    let (re, im) = complex_mul_nodes(&mut g, a_re, a_im, b_re, b_im, q);
    let re = g.push(NodeOp::Reshape(vec![b, n]), &[re]);
    let im = g.push(NodeOp::Reshape(vec![b, n]), &[im]);
    g.set_outputs(&[re, im]);
    g
}

/// Elementwise squared magnitude of a `(B, N)` complex pair:
/// `re² + im²` via two self-kernel depthwise products (§3.1) and one
/// add.  Output `(B, N)`.
pub fn magnitude_sq(b: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let q = b * n;
    let re = g.input(&[b, n]);
    let im = g.input(&[b, n]);
    let rr = ew_product_nodes(&mut g, re, re, q);
    let ii = ew_product_nodes(&mut g, im, im, q);
    let o = g.push(NodeOp::Add, &[rr, ii]);
    let o = g.push(NodeOp::Reshape(vec![b, n]), &[o]);
    g.set_outputs(&[o]);
    g
}

// ---------------------------------------------------------------------------
// IIR via unrolled iteration — the paper's iterative-function sweet spot
// ---------------------------------------------------------------------------

/// IIR filter by fixed-depth unrolled fixed-point iteration (the paper's
/// iterative-function sweet spot): one feedforward standard conv, then
/// `depth` feedback-conv + add levels.
///
/// The recurrence realized is the *prefix-aligned* (anti-causal) form
///
/// ```text
/// ff[n] = Σ_k b_taps[k] · x[n + k]                      (correlation)
/// y[n]  = ff[n] − Σ_{j=1..na} a_taps[j−1] · y[n + j]
/// ```
///
/// i.e. a causal IIR run over the time-reversed signal — chosen because
/// the movement substrate slices prefixes only.  Level `d+1` computes
/// `y⁽ᵈ⁺¹⁾[n] = ff[n] − Σ_j a[j−1]·y⁽ᵈ⁾[n+j]` from `y⁽⁰⁾ = ff`; each
/// level shortens the valid prefix by `na = a_taps.len()`, so the
/// output is `(B, W0 − depth·na)` with `W0 = L − b_taps.len() + 1`.
/// For `‖a‖₁ < 1` the truncation error contracts by `‖a‖₁` per level —
/// `dsp::iir_reference` is the exact-recurrence oracle and the property
/// tests assert the geometric bound.
pub fn iir(b: usize, l: usize, b_taps: &[f32], a_taps: &[f32], depth: usize) -> Result<Graph> {
    let mb = b_taps.len();
    let na = a_taps.len();
    if mb == 0 || na == 0 || depth == 0 {
        anyhow::bail!("iir requires non-empty b/a taps and depth >= 1");
    }
    if l < mb {
        anyhow::bail!("signal {l} shorter than {mb} feedforward taps");
    }
    let w0 = l - mb + 1;
    if w0 <= depth * na {
        anyhow::bail!(
            "unroll depth {depth} x {na} feedback taps consumes the whole {w0}-sample prefix"
        );
    }
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    // feedforward: correlation form, taps unreversed
    let kff = g.constant(Tensor::new(&[1, 1, mb], b_taps.to_vec())?);
    let bias = g.constant(Tensor::zeros(&[1]));
    let ff = g.push(NodeOp::StandardConv1d, &[xi, kff, bias]); // (B, 1, W0)
    // feedback kernel [0, -a1, ..., -a_na]: z[n] = -Σ_j a[j-1]·y[n+j]
    let mut fb = vec![0.0f32; na + 1];
    for (j, &a) in a_taps.iter().enumerate() {
        fb[j + 1] = -a;
    }
    let kfb = g.constant(Tensor::new(&[1, 1, na + 1], fb)?);
    let mut y = ff;
    let mut w = w0;
    for _ in 0..depth {
        let z = g.push(NodeOp::StandardConv1d, &[y, kfb, bias]); // (B, 1, w - na)
        w -= na;
        let ffc = g.push(
            NodeOp::StridedSlice {
                axis: 2,
                stride: 1,
                count: w,
            },
            &[ff],
        ); // prefix crop of ff to (B, 1, w)
        y = g.push(NodeOp::Add, &[ffc, z]);
    }
    let o = g.push(NodeOp::Reshape(vec![b, w]), &[y]);
    g.set_outputs(&[o]);
    Ok(g)
}

// ---------------------------------------------------------------------------
// Cross-correlation and the FX correlator (ASTRON radio-astronomy context)
// ---------------------------------------------------------------------------

/// Cross-correlation of a `(B, L)` signal against a runtime `(M,)`
/// template via one standard conv — §4.3 *without* the tap reversal
/// (correlation, not convolution).  Output `(B, L − M + 1)` with
/// `y[n] = Σ_k t[k] · x[n + k]`; `baselines::naive::xcorr` is the
/// direct O(L·M) oracle.
pub fn xcorr(b: usize, l: usize, m: usize) -> Result<Graph> {
    if m == 0 || l < m {
        anyhow::bail!("xcorr needs a template of 1..={l} taps, got {m}");
    }
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let t = g.input(&[m]);
    let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let k = g.push(NodeOp::Reshape(vec![1, 1, m]), &[t]);
    let bias = g.constant(Tensor::zeros(&[1]));
    let o = g.push(NodeOp::StandardConv1d, &[xi, k, bias]);
    let o = g.push(NodeOp::Reshape(vec![b, l - m + 1]), &[o]);
    g.set_outputs(&[o]);
    Ok(g)
}

/// A minimal two-antenna FX correlator (the ASTRON workload behind the
/// PFB use case): per-antenna STFT, per-bin gain calibration of antenna
/// 2, complex multiply against the *conjugated* calibrated spectrum,
/// and accumulation over frames:
///
/// ```text
/// V[k] = Σ_f S1[f, k] · conj(g[k] · S2[f, k])
/// ```
///
/// Inputs: two `(B, L)` antenna signals; outputs `(re, im)`
/// visibilities, each `(B, nfft)`.  The conjugation is lowered as a
/// [`FusionHint::Chain`] sign-flip depthwise conv the planner folds
/// into the gain scale (the M = 1 depthwise scale-chain fold), so the
/// compiled plan runs one combined gain-and-conjugate scale.
pub fn fx_correlate(b: usize, l: usize, nfft: usize, hop: usize, gains: &[f32]) -> Result<Graph> {
    if gains.len() != nfft {
        anyhow::bail!("need {nfft} per-bin gains, got {}", gains.len());
    }
    let mut g = Graph::new();
    let x1 = g.input(&[b, l]);
    let x2 = g.input(&[b, l]);
    let (re1, im1, frames) = stft_nodes(&mut g, x1, b, l, nfft, hop)?;
    let (re2, im2, _) = stft_nodes(&mut g, x2, b, l, nfft, hop)?;
    let rows = b * frames;

    // per-bin gain calibration of antenna 2 (M = 1 depthwise scales)
    let kg = g.constant(Tensor::new(&[nfft, 1], gains.to_vec())?);
    let bz = g.constant(Tensor::zeros(&[nfft]));
    let r2 = g.push(NodeOp::Reshape(vec![rows, nfft, 1]), &[re2]);
    let g2re = g.push(NodeOp::DepthwiseConv1d, &[r2, kg, bz]);
    let i2 = g.push(NodeOp::Reshape(vec![rows, nfft, 1]), &[im2]);
    let g2im = g.push(NodeOp::DepthwiseConv1d, &[i2, kg, bz]);

    // conjugate: negate the imaginary branch.  Tagged with
    // `FusionHint::Chain` so the planner folds the sign flip into the
    // gain scale above (after re-proving unit taps + zero bias).
    let kneg = g.constant(Tensor::new(&[nfft, 1], vec![-1.0; nfft])?);
    let g2im = g.push_with_hint(NodeOp::DepthwiseConv1d, &[g2im, kneg, bz], FusionHint::Chain);

    // V = S1 · conj(g · S2), then accumulate over frames: pointwise conv
    // on (B, F, nfft) with a ones (F, 1) kernel sums frames ascending.
    let q = rows * nfft;
    let (vre, vim) = complex_mul_nodes(&mut g, g2re, g2im, re1, im1, q);
    let vre = g.push(NodeOp::Reshape(vec![b, frames, nfft]), &[vre]);
    let vim = g.push(NodeOp::Reshape(vec![b, frames, nfft]), &[vim]);
    let ksum = g.constant(Tensor::ones(&[frames, 1]));
    let b1 = g.constant(Tensor::zeros(&[1]));
    let o_re = g.push(NodeOp::PointwiseConv, &[vre, ksum, b1]); // (B, 1, nfft)
    let o_im = g.push(NodeOp::PointwiseConv, &[vim, ksum, b1]);
    let o_re = g.push(NodeOp::Reshape(vec![b, nfft]), &[o_re]);
    let o_im = g.push(NodeOp::Reshape(vec![b, nfft]), &[o_im]);
    g.set_outputs(&[o_re, o_im]);
    Ok(g)
}

/// Delay-and-sum beamformer over `C` sensor channels: per-channel
/// integer delays via a one-hot depthwise conv, per-channel gains via
/// an M = 1 depthwise scale tagged [`FusionHint::Window`] (the planner
/// folds the gains into the delay taps — the depthwise-producer window
/// fold), then a channel sum via a ones-kernel pointwise conv.  Input
/// `(B, C, L)`; output `(B, L − D + 1)` where `D = max(delays) + 1`.
pub fn beamform(b: usize, c: usize, l: usize, delays: &[usize], gains: &[f32]) -> Result<Graph> {
    if c == 0 || delays.len() != c || gains.len() != c {
        anyhow::bail!(
            "need one delay and one gain per channel ({c}), got {} / {}",
            delays.len(),
            gains.len()
        );
    }
    let d = delays.iter().max().copied().unwrap_or(0) + 1;
    if l < d {
        anyhow::bail!("signal {l} shorter than the {d}-sample delay span");
    }
    let w = l - d + 1;
    let mut g = Graph::new();
    let x = g.input(&[b, c, l]);
    // per-channel delays: one-hot rows (the depthwise framing producer)
    let mut taps = vec![0.0f32; c * d];
    for (ch, &dl) in delays.iter().enumerate() {
        taps[ch * d + dl] = 1.0;
    }
    let kd = g.constant(Tensor::new(&[c, d], taps)?);
    let bz = g.constant(Tensor::zeros(&[c]));
    let delayed = g.push(NodeOp::DepthwiseConv1d, &[x, kd, bz]); // (B, C, W)
    // per-channel gains, foldable into the delay taps
    let kgain = g.constant(Tensor::new(&[c, 1], gains.to_vec())?);
    let gained = g.push_with_hint(
        NodeOp::DepthwiseConv1d,
        &[delayed, kgain, bz],
        FusionHint::Window,
    );
    // channel sum (ascending, matching the pointwise oracle order)
    let ks = g.constant(Tensor::ones(&[c, 1]));
    let b1 = g.constant(Tensor::zeros(&[1]));
    let o = g.push(NodeOp::PointwiseConv, &[gained, ks, b1]); // (B, 1, W)
    let o = g.push(NodeOp::Reshape(vec![b, w]), &[o]);
    g.set_outputs(&[o]);
    Ok(g)
}

// ---------------------------------------------------------------------------
// End-to-end spectrometer: PFB → |·|² → time integration, as ONE graph
// ---------------------------------------------------------------------------

/// End-to-end spectrometer compiled as ONE graph: PFB (polyphase FIR
/// bank + DFT across branches) → `|·|²` → time integration over the
/// output spectra.  Input `(B, L)`; output `(B, P)` — total power per
/// PFB channel, summed over the `Ns` spectra ascending (divide by `Ns`
/// host-side for the mean).  Every intermediate movement is a
/// contiguous reshape, so the fused plan compiles with
/// `materialize_count() == 0`.
pub fn spectrometer(b: usize, l: usize, cfg: dsp::PfbConfig) -> Result<Graph> {
    let p = cfg.branches;
    let ns = cfg.output_spectra(l)?;
    let mut g = Graph::new();
    let x = g.input(&[b, l]);
    let y = pfb_fir_nodes(&mut g, x, b, l, cfg)?; // (B, P, Ns)
    let (f_re, f_im) = dsp::dft_matrix(p);
    let bias = g.constant(Tensor::zeros(&[p]));
    let kre = g.constant(f_re);
    let kim = g.constant(f_im);
    let o_re = g.push(NodeOp::PointwiseConv, &[y, kre, bias]); // (B, P, Ns)
    let o_im = g.push(NodeOp::PointwiseConv, &[y, kim, bias]);
    // |·|² per (batch, branch, spectrum)
    let q = b * p * ns;
    let rr = ew_product_nodes(&mut g, o_re, o_re, q);
    let ii = ew_product_nodes(&mut g, o_im, o_im, q);
    let pow = g.push(NodeOp::Add, &[rr, ii]); // (1, q, 1)
    // time integration: sum the Ns spectra per (batch, branch) via a
    // ones-kernel FC (§3.4), features ascending
    let rows = g.push(NodeOp::Reshape(vec![b * p, ns]), &[pow]);
    let ksum = g.constant(Tensor::ones(&[ns, 1]));
    let b1 = g.constant(Tensor::zeros(&[1]));
    let o = g.push(NodeOp::FullyConnected, &[rows, ksum, b1]); // (B*P, 1)
    let o = g.push(NodeOp::Reshape(vec![b, p]), &[o]);
    g.set_outputs(&[o]);
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_building_blocks() {
        // The paper's Table 1, asserted structurally.
        assert_eq!(ewmult(4, 4).layer_names(), vec!["depthwise_conv1d"]);
        assert_eq!(ewadd(4, 4).layer_names(), vec!["depthwise_conv1d"]);
        assert_eq!(matmul(4, 5, 6).layer_names(), vec!["pointwise_conv"]);
        assert_eq!(summation(16).layer_names(), vec!["fully_connected"]);
        assert_eq!(
            dft(2, 8).layer_names(),
            vec!["pointwise_conv"; 2],
            "DFT of a real signal = pointwise conv (re + im kernels)"
        );
        assert_eq!(
            idft(2, 8).layer_names(),
            vec!["pointwise_conv"; 4],
            "IDFT of a complex spectrum = 4 pointwise convs"
        );
        assert_eq!(
            fir(1, 64, &[1.0; 8]).unwrap().layer_names(),
            vec!["standard_conv1d"]
        );
        assert_eq!(
            unfold(1, 64, 8).unwrap().layer_names(),
            vec!["standard_conv1d"]
        );
        let cfg = dsp::PfbConfig::new(8, 4);
        assert_eq!(
            pfb_fir(1, 64, cfg).unwrap().layer_names(),
            vec!["depthwise_conv1d"]
        );
        assert_eq!(
            pfb(1, 64, cfg).unwrap().layer_names(),
            vec!["depthwise_conv1d", "pointwise_conv", "pointwise_conv"]
        );
    }

    #[test]
    fn all_lowerings_validate() {
        ewmult(3, 7).validate().unwrap();
        ewadd(5, 2).validate().unwrap();
        matmul(3, 4, 5).validate().unwrap();
        summation(100).validate().unwrap();
        dft(2, 16).validate().unwrap();
        idft(2, 16).validate().unwrap();
        fir(2, 128, &[0.5; 16]).unwrap().validate().unwrap();
        unfold(2, 128, 8).unwrap().validate().unwrap();
        let cfg = dsp::PfbConfig::new(8, 4);
        pfb_fir(2, 8 * 32, cfg).unwrap().validate().unwrap();
        pfb(2, 8 * 32, cfg).unwrap().validate().unwrap();
    }

    #[test]
    fn output_shapes() {
        let shapes = matmul(3, 4, 5).infer_shapes().unwrap();
        let g = matmul(3, 4, 5);
        assert_eq!(shapes[g.outputs[0].0], vec![3, 5]);

        let g = unfold(2, 100, 8).unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![2, 93, 8]);

        let cfg = dsp::PfbConfig::new(8, 4);
        let g = pfb(1, 8 * 32, cfg).unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![1, 29, 8]);
        assert_eq!(shapes[g.outputs[1].0], vec![1, 29, 8]);
    }

    #[test]
    fn stft_uses_three_building_blocks() {
        let g = stft(1, 1024, 256, 128).unwrap();
        assert_eq!(
            g.layer_names(),
            vec![
                "standard_conv1d", // framing (unfold)
                "depthwise_conv1d", // windowing
                "pointwise_conv",  // DFT re
                "pointwise_conv",  // DFT im
            ]
        );
        g.validate().unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![1, 7, 256]);
    }

    #[test]
    fn stft_rejects_short_signal() {
        assert!(stft(1, 100, 256, 128).is_err());
    }

    #[test]
    fn pfb_rejects_bad_lengths() {
        let cfg = dsp::PfbConfig::new(8, 4);
        assert!(pfb_fir(1, 65, cfg).is_err()); // not divisible by P
        assert!(pfb_fir(1, 16, cfg).is_err()); // too short
    }

    #[test]
    fn new_lowerings_structure() {
        assert_eq!(
            complex_mul(2, 8).layer_names(),
            vec!["depthwise_conv1d"; 4],
            "complex multiply = 4 elementwise depthwise products"
        );
        assert_eq!(
            magnitude_sq(2, 8).layer_names(),
            vec!["depthwise_conv1d"; 2]
        );
        assert_eq!(
            iir(1, 64, &[0.5, 0.25], &[0.3], 3).unwrap().layer_names(),
            vec!["standard_conv1d"; 4],
            "feedforward + depth unrolled feedback levels"
        );
        assert_eq!(
            xcorr(1, 64, 8).unwrap().layer_names(),
            vec!["standard_conv1d"]
        );
        assert_eq!(
            beamform(1, 4, 64, &[0, 1, 2, 3], &[1.0, 0.8, -0.6, 0.4])
                .unwrap()
                .layer_names(),
            vec!["depthwise_conv1d", "depthwise_conv1d", "pointwise_conv"]
        );
        let cfg = dsp::PfbConfig::new(8, 4);
        assert_eq!(
            spectrometer(1, 8 * 32, cfg).unwrap().layer_names(),
            vec![
                "depthwise_conv1d", // polyphase FIR bank
                "pointwise_conv",   // DFT re
                "pointwise_conv",   // DFT im
                "depthwise_conv1d", // re²
                "depthwise_conv1d", // im²
                "fully_connected",  // time integration
            ]
        );
    }

    #[test]
    fn new_lowerings_validate_and_shape() {
        complex_mul(3, 5).validate().unwrap();
        magnitude_sq(3, 5).validate().unwrap();

        let g = iir(2, 64, &[0.5, 0.25], &[0.3, 0.1], 3).unwrap();
        g.validate().unwrap();
        // W0 = 64 - 2 + 1 = 63, minus depth(3) * na(2)
        assert_eq!(g.infer_shapes().unwrap()[g.outputs[0].0], vec![2, 57]);

        let g = xcorr(2, 100, 9).unwrap();
        g.validate().unwrap();
        assert_eq!(g.infer_shapes().unwrap()[g.outputs[0].0], vec![2, 92]);

        let g = fx_correlate(1, 512, 64, 32, &[1.0; 64]).unwrap();
        g.validate().unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![1, 64]);
        assert_eq!(shapes[g.outputs[1].0], vec![1, 64]);

        let g = beamform(2, 4, 64, &[3, 0, 1, 2], &[0.5; 4]).unwrap();
        g.validate().unwrap();
        assert_eq!(g.infer_shapes().unwrap()[g.outputs[0].0], vec![2, 61]);

        let cfg = dsp::PfbConfig::new(8, 4);
        let g = spectrometer(2, 8 * 32, cfg).unwrap();
        g.validate().unwrap();
        assert_eq!(g.infer_shapes().unwrap()[g.outputs[0].0], vec![2, 8]);
    }

    #[test]
    fn new_lowerings_reject_bad_configs() {
        assert!(iir(1, 4, &[1.0; 8], &[0.5], 2).is_err()); // signal < ff taps
        assert!(iir(1, 16, &[1.0], &[0.5; 4], 4).is_err()); // depth eats prefix
        assert!(iir(1, 16, &[1.0], &[], 1).is_err()); // no feedback taps
        assert!(iir(1, 16, &[1.0], &[0.5], 0).is_err()); // zero depth
        assert!(xcorr(1, 8, 9).is_err()); // template longer than signal
        assert!(xcorr(1, 8, 0).is_err()); // empty template
        assert!(fx_correlate(1, 32, 64, 32, &[1.0; 64]).is_err()); // short signal
        assert!(fx_correlate(1, 512, 64, 32, &[1.0; 8]).is_err()); // wrong gain count
        assert!(beamform(1, 4, 2, &[0, 1, 2, 3], &[1.0; 4]).is_err()); // span > signal
        assert!(beamform(1, 4, 64, &[0, 1], &[1.0; 4]).is_err()); // delays != channels
        let cfg = dsp::PfbConfig::new(8, 4);
        assert!(spectrometer(1, 65, cfg).is_err()); // not divisible by P
    }

    #[test]
    fn fold_hints_are_attached() {
        let g = fx_correlate(1, 512, 64, 32, &[1.0; 64]).unwrap();
        let chains = g
            .nodes
            .iter()
            .filter(|n| n.hint == FusionHint::Chain)
            .count();
        assert_eq!(
            chains, 1,
            "one conjugate sign-flip tagged for the scale-chain fold"
        );
        let g = beamform(1, 4, 64, &[0, 1, 2, 3], &[1.0, 0.8, -0.6, 0.4]).unwrap();
        let wins = g
            .nodes
            .iter()
            .filter(|n| n.hint == FusionHint::Window)
            .count();
        assert_eq!(wins, 1, "gains tagged for the depthwise window fold");
    }
}
