//! Dataflow IR for TINA plans: a topologically-ordered list of nodes over
//! the four building-block layers plus the data-movement glue (§3's
//! reshapes) and the complex-arithmetic combiners the Fourier mappings
//! need.
//!
//! Kernels and biases are ordinary values — constants when the weight is
//! baked (FIR taps, DFM) and graph inputs when it is a runtime operand
//! (e.g. the second matrix of an elementwise multiply), matching how the
//! jax side closes over constants.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Index of a value produced by an input or node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub usize);

/// Node operation.  Layer nodes take inputs [x, kernel, bias].
#[derive(Debug, Clone, PartialEq)]
pub enum NodeOp {
    /// Eq. (1): inputs [x (T,Cin,W), k (Cout,Cin,N), b (Cout)].
    StandardConv1d,
    /// Eq. (2): inputs [x (T,C,W), k (C,M), b (C)].
    DepthwiseConv1d,
    /// Eq. (3): inputs [x (T,Cin,S), k (Cin,Cout), b (Cout)].
    PointwiseConv,
    /// Eq. (4): inputs [x (B,Cin), k (Cin,Cout), b (Cout)].
    FullyConnected,
    /// Materialized weight/bias.
    Constant(Tensor),
    /// Shape glue.
    Reshape(Vec<usize>),
    /// 2-D transpose (shape glue).
    Transpose2,
    /// Rank-3 axis permutation (shape glue).
    Permute3([usize; 3]),
    /// Keep `count` elements at multiples of `stride` along `axis`
    /// (the stride parameter of paper §2.1, used by the STFT extension op).
    StridedSlice {
        /// Axis sliced along.
        axis: usize,
        /// Step between kept indices.
        stride: usize,
        /// Number of kept indices.
        count: usize,
    },
    /// Elementwise sum — (re, im) complex plumbing.
    Add,
    /// Elementwise difference — (re, im) complex plumbing.
    Sub,
}

impl NodeOp {
    /// True if this is one of the four TINA building blocks.
    pub fn is_layer(&self) -> bool {
        matches!(
            self,
            NodeOp::StandardConv1d
                | NodeOp::DepthwiseConv1d
                | NodeOp::PointwiseConv
                | NodeOp::FullyConnected
        )
    }

    /// True for the pure data-movement ops the planned executor must compile
    /// to stride rewrites, never copies (`Reshape` is movement too, but may
    /// legitimately force a copy when a strided view cannot be re-grouped).
    pub fn is_strided_movement(&self) -> bool {
        matches!(
            self,
            NodeOp::Transpose2 | NodeOp::Permute3(_) | NodeOp::StridedSlice { .. }
        )
    }

    /// Human name used in plan dumps and tests.
    pub fn name(&self) -> &'static str {
        match self {
            NodeOp::StandardConv1d => "standard_conv1d",
            NodeOp::DepthwiseConv1d => "depthwise_conv1d",
            NodeOp::PointwiseConv => "pointwise_conv",
            NodeOp::FullyConnected => "fully_connected",
            NodeOp::Constant(_) => "constant",
            NodeOp::Reshape(_) => "reshape",
            NodeOp::Transpose2 => "transpose2",
            NodeOp::Permute3(_) => "permute3",
            NodeOp::StridedSlice { .. } => "strided_slice",
            NodeOp::Add => "add",
            NodeOp::Sub => "sub",
        }
    }
}

/// Planner hint attached to a node by a lowering.  Hints are advisory:
/// the plan compiler re-proves every safety and rounding precondition
/// before acting on one, so a wrong (or missing) hint costs a skipped
/// optimization, never a wrong result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FusionHint {
    /// No hint.
    #[default]
    None,
    /// An elementwise window multiply (depthwise conv with M = 1 and a
    /// baked kernel) the lowering expects the planner to fold into the
    /// upstream framing convolution by pre-scaling its taps — the STFT
    /// window fold (see `exec::plan`'s fusion-pass docs for the exact
    /// preconditions and the rounding contract).
    Window,
    /// A per-channel sign flip / selector (depthwise conv with M = 1,
    /// all taps in {+1, -1} and zero bias) the lowering expects the
    /// planner to fold into its upstream M = 1 depthwise scale producer
    /// by pre-signing that producer's taps and bias — the scale-chain
    /// fold (see `exec::plan`'s fusion-pass docs). Restricting the
    /// consumer to unit taps keeps the rewrite exactly
    /// rounding-preserving.
    Chain,
}

/// A graph node: op + input value ids.  Produces exactly one value.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// The node's operation.
    pub op: NodeOp,
    /// Input value ids in operand order.
    pub inputs: Vec<ValueId>,
    /// Advisory planner hint (see [`FusionHint`]).
    pub hint: FusionHint,
}

/// A TINA plan: inputs, nodes in topological order, outputs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Graph {
    /// (value id, shape) of each external input, in call order.
    pub inputs: Vec<(ValueId, Vec<usize>)>,
    /// Nodes in topological order.
    pub nodes: Vec<Node>,
    /// Output value ids in declaration order.
    pub outputs: Vec<ValueId>,
    next_id: usize,
}

impl Graph {
    /// Empty graph.
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Declare an external input with a static shape.
    pub fn input(&mut self, shape: &[usize]) -> ValueId {
        let id = ValueId(self.next_id);
        self.next_id += 1;
        self.inputs.push((id, shape.to_vec()));
        id
    }

    /// Append a node; inputs must already exist (enforces topo order).
    pub fn push(&mut self, op: NodeOp, inputs: &[ValueId]) -> ValueId {
        self.push_with_hint(op, inputs, FusionHint::None)
    }

    /// Append a node carrying an advisory [`FusionHint`] for the planner.
    pub fn push_with_hint(
        &mut self,
        op: NodeOp,
        inputs: &[ValueId],
        hint: FusionHint,
    ) -> ValueId {
        for i in inputs {
            assert!(i.0 < self.next_id, "node input {i:?} not yet defined");
        }
        let id = ValueId(self.next_id);
        self.next_id += 1;
        self.nodes.push(Node {
            op,
            inputs: inputs.to_vec(),
            hint,
        });
        id
    }

    /// Append a baked-constant node.
    pub fn constant(&mut self, t: Tensor) -> ValueId {
        self.push(NodeOp::Constant(t), &[])
    }

    /// Declare the graph outputs.
    pub fn set_outputs(&mut self, outs: &[ValueId]) {
        self.outputs = outs.to_vec();
    }

    /// Total number of values (inputs + node outputs).
    pub fn value_count(&self) -> usize {
        self.next_id
    }

    /// Map a ValueId to the producing node index, if it is a node output.
    pub fn producer(&self, v: ValueId) -> Option<usize> {
        let n_inputs = self.inputs.len();
        if v.0 < n_inputs {
            None
        } else {
            Some(v.0 - n_inputs)
        }
    }

    /// Names of the building-block layers in execution order (the paper's
    /// Table 1 "building blocks" column — asserted by mapping tests).
    pub fn layer_names(&self) -> Vec<&'static str> {
        self.nodes
            .iter()
            .filter(|n| n.op.is_layer())
            .map(|n| n.op.name())
            .collect()
    }

    /// Static shape inference over the whole graph.  Returns one shape per
    /// value id; errors on any inconsistency.
    pub fn infer_shapes(&self) -> Result<Vec<Vec<usize>>> {
        let mut shapes: Vec<Option<Vec<usize>>> = vec![None; self.value_count()];
        for (id, shape) in &self.inputs {
            shapes[id.0] = Some(shape.clone());
        }
        let n_inputs = self.inputs.len();
        for (i, node) in self.nodes.iter().enumerate() {
            let out_id = n_inputs + i;
            let get = |v: ValueId| -> Result<&Vec<usize>> {
                shapes[v.0]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("value {v:?} used before defined"))
            };
            let out_shape: Vec<usize> = match &node.op {
                NodeOp::Constant(t) => t.shape().to_vec(),
                NodeOp::Reshape(target) => {
                    let src = get(node.inputs[0])?;
                    let n: usize = src.iter().product();
                    let m: usize = target.iter().product();
                    if n != m {
                        bail!("reshape {:?} -> {:?} changes element count", src, target);
                    }
                    target.clone()
                }
                NodeOp::Transpose2 => {
                    let s = get(node.inputs[0])?;
                    if s.len() != 2 {
                        bail!("transpose2 on rank {} value", s.len());
                    }
                    vec![s[1], s[0]]
                }
                NodeOp::Permute3(p) => {
                    let s = get(node.inputs[0])?;
                    if s.len() != 3 {
                        bail!("permute3 on rank {} value", s.len());
                    }
                    vec![s[p[0]], s[p[1]], s[p[2]]]
                }
                NodeOp::StridedSlice { axis, stride, count } => {
                    let s = get(node.inputs[0])?;
                    if *axis >= s.len() {
                        bail!("strided_slice axis {axis} out of range for {s:?}");
                    }
                    if *stride == 0 || *count == 0 || (*count - 1) * *stride >= s[*axis] {
                        bail!(
                            "strided_slice (stride {stride}, count {count}) out of range for {s:?}"
                        );
                    }
                    let mut out = s.clone();
                    out[*axis] = *count;
                    out
                }
                NodeOp::Add | NodeOp::Sub => {
                    let a = get(node.inputs[0])?;
                    let b = get(node.inputs[1])?;
                    if a != b {
                        bail!("elementwise combiner shape mismatch {:?} vs {:?}", a, b);
                    }
                    a.clone()
                }
                NodeOp::DepthwiseConv1d => {
                    let x = get(node.inputs[0])?.clone();
                    let k = get(node.inputs[1])?.clone();
                    let b = get(node.inputs[2])?.clone();
                    if x.len() != 3 || k.len() != 2 || b.len() != 1 {
                        bail!("depthwise rank error: x{x:?} k{k:?} b{b:?}");
                    }
                    if x[1] != k[0] || b[0] != x[1] {
                        bail!("depthwise channel mismatch: x{x:?} k{k:?} b{b:?}");
                    }
                    if x[2] < k[1] {
                        bail!("depthwise window too long: x{x:?} k{k:?}");
                    }
                    vec![x[0], x[1], x[2] - k[1] + 1]
                }
                NodeOp::StandardConv1d => {
                    let x = get(node.inputs[0])?.clone();
                    let k = get(node.inputs[1])?.clone();
                    let b = get(node.inputs[2])?.clone();
                    if x.len() != 3 || k.len() != 3 || b.len() != 1 {
                        bail!("standard conv rank error: x{x:?} k{k:?} b{b:?}");
                    }
                    if x[1] != k[1] || b[0] != k[0] {
                        bail!("standard conv shape mismatch: x{x:?} k{k:?} b{b:?}");
                    }
                    if x[2] < k[2] {
                        bail!("standard conv window too long: x{x:?} k{k:?}");
                    }
                    vec![x[0], k[0], x[2] - k[2] + 1]
                }
                NodeOp::PointwiseConv => {
                    let x = get(node.inputs[0])?.clone();
                    let k = get(node.inputs[1])?.clone();
                    let b = get(node.inputs[2])?.clone();
                    if x.len() != 3 || k.len() != 2 || b.len() != 1 {
                        bail!("pointwise rank error: x{x:?} k{k:?} b{b:?}");
                    }
                    if x[1] != k[0] || b[0] != k[1] {
                        bail!("pointwise shape mismatch: x{x:?} k{k:?} b{b:?}");
                    }
                    vec![x[0], k[1], x[2]]
                }
                NodeOp::FullyConnected => {
                    let x = get(node.inputs[0])?.clone();
                    let k = get(node.inputs[1])?.clone();
                    let b = get(node.inputs[2])?.clone();
                    if x.len() != 2 || k.len() != 2 || b.len() != 1 {
                        bail!("fc rank error: x{x:?} k{k:?} b{b:?}");
                    }
                    if x[1] != k[0] || b[0] != k[1] {
                        bail!("fc shape mismatch: x{x:?} k{k:?} b{b:?}");
                    }
                    vec![x[0], k[1]]
                }
            };
            shapes[out_id] = Some(out_shape);
        }
        for out in &self.outputs {
            if shapes[out.0].is_none() {
                bail!("graph output {out:?} has no producer");
            }
        }
        Ok(shapes.into_iter().map(|s| s.unwrap_or_default()).collect())
    }

    /// Validate structure: inputs used consistently, outputs defined, all
    /// shapes inferable.
    pub fn validate(&self) -> Result<()> {
        if self.outputs.is_empty() {
            bail!("graph has no outputs");
        }
        self.infer_shapes().map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_graph() -> Graph {
        // (2, 8) input -> reshape (1, 2, 8) -> depthwise M=3 -> (1, 2, 6)
        let mut g = Graph::new();
        let x = g.input(&[2, 8]);
        let r = g.push(NodeOp::Reshape(vec![1, 2, 8]), &[x]);
        let k = g.constant(Tensor::ones(&[2, 3]));
        let b = g.constant(Tensor::zeros(&[2]));
        let o = g.push(NodeOp::DepthwiseConv1d, &[r, k, b]);
        g.set_outputs(&[o]);
        g
    }

    #[test]
    fn shape_inference_chain() {
        let g = chain_graph();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.outputs[0].0], vec![1, 2, 6]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn layer_names_reported() {
        let g = chain_graph();
        assert_eq!(g.layer_names(), vec!["depthwise_conv1d"]);
    }

    #[test]
    fn reshape_count_checked() {
        let mut g = Graph::new();
        let x = g.input(&[4]);
        g.push(NodeOp::Reshape(vec![5]), &[x]);
        g.set_outputs(&[ValueId(1)]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn channel_mismatch_detected() {
        let mut g = Graph::new();
        let x = g.input(&[1, 3, 8]);
        let k = g.constant(Tensor::ones(&[2, 3])); // wrong channel count
        let b = g.constant(Tensor::zeros(&[3]));
        let o = g.push(NodeOp::DepthwiseConv1d, &[x, k, b]);
        g.set_outputs(&[o]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn no_outputs_invalid() {
        let mut g = Graph::new();
        g.input(&[1]);
        assert!(g.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "not yet defined")]
    fn forward_reference_panics() {
        let mut g = Graph::new();
        let _ = g.input(&[1]);
        g.push(NodeOp::Add, &[ValueId(5), ValueId(6)]);
    }
}
