//! The NumPy-on-CPU analog: straightforward single-threaded
//! implementations of every benchmarked op.  Clarity over speed — this is
//! the paper's baseline denominator, not a contender.

use crate::dsp::{self, PfbConfig};
use crate::tensor::{ComplexTensor, Tensor};
use anyhow::{bail, Result};

/// Elementwise multiply (Fig. 1a).
pub fn ewmult(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    crate::tensor::mul(a, b)
}

/// Elementwise add (Fig. 1c).
pub fn ewadd(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    crate::tensor::add(a, b)
}

/// Matrix-matrix multiply (Fig. 1b): triple loop.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    crate::tensor::matmul(a, b)
}

/// Summation (Fig. 1d): sequential accumulation.
pub fn summation(x: &Tensor) -> f32 {
    // deliberately the simplest possible loop (numpy's np.sum is smarter,
    // but this is the "naive CPU" yardstick; accuracy checked to tolerance)
    let mut acc = 0.0f64;
    for &v in x.data() {
        acc += v as f64;
    }
    acc as f32
}

/// DFT of (B, N) real or complex data (Fig. 2a): direct O(N^2).
pub fn dft(x: &ComplexTensor) -> Result<ComplexTensor> {
    dsp::dft_direct(x)
}

/// IDFT via the inverse DFM (Fig. 2b): direct O(N^2).
pub fn idft(z: &ComplexTensor) -> Result<ComplexTensor> {
    if z.re.rank() != 2 {
        bail!("idft expects (B, N)");
    }
    let n = z.shape()[1];
    let (ifr, ifi) = dsp::idft_matrix(n);
    z.matmul(&ComplexTensor::new(ifr, ifi)?)
}

/// FIR filter, valid mode (Fig. 2c): y(i) = sum_k a(k) x(i + M - 1 - k).
pub fn fir(x: &Tensor, taps: &[f32]) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("fir expects (B, L), got {:?}", x.shape());
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    let m = taps.len();
    if l < m {
        bail!("signal shorter than filter");
    }
    let wout = l - m + 1;
    let mut out = Tensor::zeros(&[b, wout]);
    for bi in 0..b {
        let row = &x.data()[bi * l..(bi + 1) * l];
        let orow = &mut out.data_mut()[bi * wout..(bi + 1) * wout];
        for (i, o) in orow.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (k, &a) in taps.iter().enumerate() {
                acc += a * row[i + m - 1 - k];
            }
            *o = acc;
        }
    }
    Ok(out)
}

/// Cross-correlation against a template, valid mode:
/// y(i) = sum_k t(k) x(i + k).  Direct O(L·M), ascending-tap
/// accumulation to match the conv kernel's oracle reduction order.
pub fn xcorr(x: &Tensor, template: &[f32]) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("xcorr expects (B, L), got {:?}", x.shape());
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    let m = template.len();
    if m == 0 || l < m {
        bail!("template empty or longer than signal");
    }
    let wout = l - m + 1;
    let mut out = Tensor::zeros(&[b, wout]);
    for bi in 0..b {
        let row = &x.data()[bi * l..(bi + 1) * l];
        let orow = &mut out.data_mut()[bi * wout..(bi + 1) * wout];
        for (i, o) in orow.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (k, &t) in template.iter().enumerate() {
                acc += t * row[i + k];
            }
            *o = acc;
        }
    }
    Ok(out)
}

/// Unfolding (Fig. 2d): Y[i, j] = X[i + j], per batch row.
pub fn unfold(x: &Tensor, window: usize) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("unfold expects (B, L), got {:?}", x.shape());
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    if l < window {
        bail!("window longer than signal");
    }
    let wout = l - window + 1;
    let mut out = Tensor::zeros(&[b, wout, window]);
    for bi in 0..b {
        let row = &x.data()[bi * l..(bi + 1) * l];
        let obase = bi * wout * window;
        for i in 0..wout {
            for j in 0..window {
                out.data_mut()[obase + i * window + j] = row[i + j];
            }
        }
    }
    Ok(out)
}

/// STFT (extension op): frame, window, direct DFT per frame.
pub fn stft(x: &Tensor, nfft: usize, hop: usize) -> Result<(Tensor, Tensor)> {
    if x.rank() != 2 {
        bail!("stft expects (B, L), got {:?}", x.shape());
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    if l < nfft {
        bail!("signal shorter than one frame");
    }
    let frames = (l - nfft) / hop + 1;
    let win = dsp::hamming(nfft);
    let mut rows = Tensor::zeros(&[b * frames, nfft]);
    for bi in 0..b {
        for f in 0..frames {
            for i in 0..nfft {
                rows.data_mut()[(bi * frames + f) * nfft + i] =
                    x.data()[bi * l + f * hop + i] * win[i] as f32;
            }
        }
    }
    let z = dsp::dft_direct(&ComplexTensor::from_real(rows))?;
    Ok((
        z.re.into_reshape(&[b, frames, nfft])?,
        z.im.into_reshape(&[b, frames, nfft])?,
    ))
}

/// PFB FIR bank (Fig. 3 left): defers to the dsp reference (which is the
/// clear scalar implementation already).
pub fn pfb_fir(x: &Tensor, cfg: PfbConfig) -> Result<Tensor> {
    dsp::pfb::pfb_fir_reference(x, cfg)
}

/// Full PFB (Fig. 3 right): FIR bank + direct DFT across branches.
pub fn pfb(x: &Tensor, cfg: PfbConfig) -> Result<ComplexTensor> {
    dsp::pfb_reference(x, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stft_single_tone_concentrates_in_bin() {
        // tone at bin 8 of a 64-point frame
        let n = 64;
        let l = 640;
        let data: Vec<f32> = (0..l)
            .map(|i| (2.0 * std::f64::consts::PI * 8.0 * i as f64 / n as f64).cos() as f32)
            .collect();
        let x = Tensor::new(&[1, l], data).unwrap();
        let (re, im) = stft(&x, n, n / 2).unwrap();
        let frames = re.shape()[1];
        for f in 0..frames {
            let power: Vec<f32> = (0..n)
                .map(|k| re.at(&[0, f, k]).powi(2) + im.at(&[0, f, k]).powi(2))
                .collect();
            let peak = (0..n).max_by(|&a, &b| power[a].total_cmp(&power[b])).unwrap();
            assert!(peak == 8 || peak == n - 8, "frame {f} peak {peak}");
        }
    }

    #[test]
    fn stft_frame_count() {
        let x = Tensor::zeros(&[1, 1000]);
        let (re, _) = stft(&x, 256, 128).unwrap();
        assert_eq!(re.shape(), &[1, (1000 - 256) / 128 + 1, 256]);
        assert!(stft(&Tensor::zeros(&[1, 100]), 256, 128).is_err());
    }

    #[test]
    fn fir_impulse_recovers_taps_reversed() {
        // x = unit impulse at position M-1 -> y(0) = a(0) ... actually
        // y(i) = sum_k a(k) x(i+M-1-k); impulse at M-1 gives y(i) = a(i).
        let m = 5;
        let mut x = Tensor::zeros(&[1, 16]);
        x.set(&[0, m - 1], 1.0);
        let taps: Vec<f32> = (1..=m).map(|i| i as f32).collect();
        let y = fir(&x, &taps).unwrap();
        for (i, &t) in taps.iter().enumerate() {
            assert_eq!(y.at(&[0, i]), t, "tap {i}");
        }
    }

    #[test]
    fn fir_matches_moving_average() {
        let x = Tensor::new(&[1, 6], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let y = fir(&x, &[0.5, 0.5]).unwrap();
        assert_eq!(y.data(), &[1.5, 2.5, 3.5, 4.5, 5.5]);
    }

    #[test]
    fn unfold_example_from_paper() {
        // paper §4.4: X=[1,2,3,4], J=2 -> Y=[[1,2],[2,3],[3,4]]
        let x = Tensor::new(&[1, 4], vec![1., 2., 3., 4.]).unwrap();
        let y = unfold(&x, 2).unwrap();
        assert_eq!(y.shape(), &[1, 3, 2]);
        assert_eq!(y.data(), &[1., 2., 2., 3., 3., 4.]);
    }

    #[test]
    fn summation_matches_pairwise() {
        let x = Tensor::randn(&[10_000], 3);
        let naive = summation(&x);
        let pairwise = crate::tensor::sum(&x);
        assert!((naive - pairwise).abs() < 1e-2, "{naive} vs {pairwise}");
    }

    #[test]
    fn idft_inverts_dft() {
        let x = ComplexTensor::from_real(Tensor::randn(&[2, 16], 4));
        let z = dft(&x).unwrap();
        let back = idft(&z).unwrap();
        assert!(back.allclose(&x, 1e-4, 1e-4));
    }

    #[test]
    fn shape_errors() {
        assert!(fir(&Tensor::zeros(&[4]), &[1.0]).is_err());
        assert!(fir(&Tensor::zeros(&[1, 2]), &[1.0, 1.0, 1.0]).is_err());
        assert!(unfold(&Tensor::zeros(&[1, 3]), 5).is_err());
    }
}
