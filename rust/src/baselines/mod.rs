//! CPU comparator implementations for the paper's evaluation (DESIGN.md §3):
//!
//! * [`naive`] — the **NumPy-on-CPU analog**: clear, single-threaded,
//!   per-op scalar code.  This is the denominator of every Fig. 3 speedup.
//! * [`optimized`] — the **CuPy analog**: per-op vendor-quality native code
//!   (blocked matmul, multithreading, radix-2 FFT) but *no* cross-op graph
//!   fusion, which is exactly what distinguishes CuPy from the compiled
//!   TINA/JAX graphs in the paper.
//!
//! Both expose the same op surface as the TINA artifacts so the bench
//! harness can sweep implementations uniformly.

pub mod naive;
pub mod optimized;
