//! The CuPy analog: per-op optimized native implementations — cache-blocked
//! matmul, multithreaded loops (scoped threads), radix-2 FFT — but **no
//! cross-op fusion**.  Each op reads and writes full arrays, exactly like a
//! sequence of library kernel launches.
//!
//! Threading is gated on a size threshold so small inputs don't pay spawn
//! overhead (mirroring how GPU launches dominate small CuPy ops).

use crate::dsp::{self, PfbConfig};
use crate::tensor::{ComplexTensor, Tensor};
use crate::util::threadpool::{default_threads, parallel_for, SendPtr};
use anyhow::{bail, Result};

/// Below this element count, run single-threaded.
const PAR_THRESHOLD: usize = 64 * 1024;

fn threads_for(n: usize) -> usize {
    if n < PAR_THRESHOLD {
        1
    } else {
        default_threads()
    }
}

/// Elementwise multiply: chunked, auto-vectorizable inner loop.
pub fn ewmult(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() {
        bail!("shape mismatch");
    }
    let n = a.len();
    let mut out = vec![0.0f32; n];
    let (ad, bd) = (a.data(), b.data());
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(n), n, |start, stop| {
        // SAFETY: disjoint ranges per thread.
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.at(start), stop - start) };
        for (i, oi) in o.iter_mut().enumerate() {
            *oi = ad[start + i] * bd[start + i];
        }
    });
    Tensor::new(a.shape(), out)
}

/// Elementwise add.
pub fn ewadd(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() {
        bail!("shape mismatch");
    }
    let n = a.len();
    let mut out = vec![0.0f32; n];
    let (ad, bd) = (a.data(), b.data());
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(n), n, |start, stop| {
        // SAFETY: disjoint index ranges per thread; `out` outlives the
        // scoped threads.
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.at(start), stop - start) };
        for (i, oi) in o.iter_mut().enumerate() {
            *oi = ad[start + i] + bd[start + i];
        }
    });
    Tensor::new(a.shape(), out)
}

/// Cache-blocked (i-k-j order) matmul, rows parallelized.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.rank() != 2 || b.rank() != 2 {
        bail!("matmul needs rank-2 operands");
    }
    let (m, l) = (a.shape()[0], a.shape()[1]);
    let (l2, n) = (b.shape()[0], b.shape()[1]);
    if l != l2 {
        bail!("contraction mismatch: {l} vs {l2}");
    }
    let mut out = vec![0.0f32; m * n];
    let (ad, bd) = (a.data(), b.data());
    const BK: usize = 64; // L1-friendly k-block

    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(m * n * l), m, |row_start, row_stop| {
        // SAFETY: disjoint row ranges per thread map to disjoint
        // [row_start*n, row_stop*n) spans of `out`, which outlives the
        // scoped threads.
        let o = unsafe {
            std::slice::from_raw_parts_mut(out_ptr.at(row_start * n), (row_stop - row_start) * n)
        };
        for k0 in (0..l).step_by(BK) {
            let k1 = (k0 + BK).min(l);
            for i in row_start..row_stop {
                let orow = &mut o[(i - row_start) * n..(i - row_start + 1) * n];
                for k in k0..k1 {
                    let aik = ad[i * l + k];
                    let brow = &bd[k * n..(k + 1) * n];
                    for (ov, &bv) in orow.iter_mut().zip(brow) {
                        *ov += aik * bv;
                    }
                }
            }
        }
    });
    Tensor::new(&[m, n], out)
}

/// Summation: per-thread partial sums, pairwise within chunks.
pub fn summation(x: &Tensor) -> f32 {
    let n = x.len();
    let t = threads_for(n);
    if t == 1 {
        return crate::tensor::sum(x);
    }
    let data = x.data();
    let partials = std::sync::Mutex::new(vec![0.0f64; 0]);
    parallel_for(t, n, |start, stop| {
        let mut acc = 0.0f64;
        for &v in &data[start..stop] {
            acc += v as f64;
        }
        partials.lock().unwrap().push(acc);
    });
    let total: f64 = partials.lock().unwrap().iter().sum();
    total as f32
}

/// FFT-based DFT (the cuFFT analog).  Falls back to the direct DFT for
/// non-power-of-two lengths.
pub fn dft(x: &ComplexTensor) -> Result<ComplexTensor> {
    let n = x.shape()[1];
    if n.is_power_of_two() {
        dsp::fft_radix2(x)
    } else {
        dsp::dft_direct(x)
    }
}

/// Inverse FFT via conjugation: ifft(z) = conj(fft(conj(z))) / N.
pub fn idft(z: &ComplexTensor) -> Result<ComplexTensor> {
    let n = z.shape()[1];
    let conj = ComplexTensor::new(z.re.clone(), crate::tensor::scale(&z.im, -1.0))?;
    let f = dft(&conj)?;
    let scale = 1.0 / n as f32;
    ComplexTensor::new(
        crate::tensor::scale(&f.re, scale),
        crate::tensor::scale(&f.im, -scale),
    )
}

/// FIR: inner loop unrolled over taps with the signal chunked across
/// threads (each output element is independent).
pub fn fir(x: &Tensor, taps: &[f32]) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("fir expects (B, L)");
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    let m = taps.len();
    if l < m {
        bail!("signal shorter than filter");
    }
    let wout = l - m + 1;
    // reversed taps once: y(i) = sum_j rev[j] * x[i + j]
    let rev: Vec<f32> = taps.iter().rev().copied().collect();
    let mut out = vec![0.0f32; b * wout];
    let data = x.data();
    let out_ptr = SendPtr(out.as_mut_ptr());
    for bi in 0..b {
        let row = &data[bi * l..(bi + 1) * l];
        parallel_for(threads_for(wout * m), wout, |start, stop| {
            // SAFETY: within one batch row, threads get disjoint output
            // ranges [start, stop); batch rows are processed serially,
            // so no two writes to `out` ever overlap.
            let o = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.at(bi * wout + start), stop - start)
            };
            for (i, ov) in o.iter_mut().enumerate() {
                let base = start + i;
                let mut acc = 0.0f32;
                for (j, &t) in rev.iter().enumerate() {
                    acc += t * row[base + j];
                }
                *ov = acc;
            }
        });
    }
    Tensor::new(&[b, wout], out)
}

/// Unfold: memcpy rows (each output row is a contiguous slice of x).
pub fn unfold(x: &Tensor, window: usize) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("unfold expects (B, L)");
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    if l < window {
        bail!("window longer than signal");
    }
    let wout = l - window + 1;
    let mut out = vec![0.0f32; b * wout * window];
    let data = x.data();
    let out_ptr = SendPtr(out.as_mut_ptr());
    for bi in 0..b {
        let row = &data[bi * l..(bi + 1) * l];
        parallel_for(threads_for(wout * window), wout, |start, stop| {
            // SAFETY: within one batch row, threads get disjoint window
            // ranges [start, stop), i.e. disjoint spans of `out`; batch
            // rows are processed serially.
            let o = unsafe {
                std::slice::from_raw_parts_mut(
                    out_ptr.at((bi * wout + start) * window),
                    (stop - start) * window,
                )
            };
            for i in 0..(stop - start) {
                o[i * window..(i + 1) * window]
                    .copy_from_slice(&row[start + i..start + i + window]);
            }
        });
    }
    Tensor::new(&[b, wout, window], out)
}

/// PFB FIR bank: branch-major loop with unrolled taps, branches
/// parallelized across threads.
pub fn pfb_fir(x: &Tensor, cfg: PfbConfig) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("pfb_fir expects (B, L)");
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    let (p, m) = (cfg.branches, cfg.taps_per_branch);
    let ns_out = cfg.output_spectra(l)?;
    let bank = cfg.bank()?; // (P, M) row-major
    let mut out = vec![0.0f32; b * p * ns_out];
    let data = x.data();
    let out_ptr = SendPtr(out.as_mut_ptr());
    for bi in 0..b {
        let row = &data[bi * l..(bi + 1) * l];
        parallel_for(threads_for(p * ns_out * m), p, |p_start, p_stop| {
            // SAFETY: within one batch row, threads get disjoint branch
            // ranges [p_start, p_stop), i.e. disjoint spans of `out`;
            // batch rows are processed serially.
            let o = unsafe {
                std::slice::from_raw_parts_mut(
                    out_ptr.at((bi * p + p_start) * ns_out),
                    (p_stop - p_start) * ns_out,
                )
            };
            for pi in p_start..p_stop {
                let taps = &bank[pi * m..(pi + 1) * m];
                let orow = &mut o[(pi - p_start) * ns_out..(pi - p_start + 1) * ns_out];
                for (n, ov) in orow.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    // x_p(n') = x[n' * P + p]
                    for (t, &h) in taps.iter().enumerate() {
                        acc += h * row[(n + m - 1 - t) * p + pi];
                    }
                    *ov = acc;
                }
            }
        });
    }
    Tensor::new(&[b, p, ns_out], out)
}

/// Full PFB: FIR bank + FFT across branches (power-of-two P) — the
/// CuPy pipeline of separate kernel launches.
pub fn pfb(x: &Tensor, cfg: PfbConfig) -> Result<ComplexTensor> {
    let y = pfb_fir(x, cfg)?; // (B, P, Ns)
    let (b, p, ns) = (y.shape()[0], y.shape()[1], y.shape()[2]);
    // gather spectra rows: (B*Ns, P) then FFT each row
    let mut rows = vec![0.0f32; b * ns * p];
    for bi in 0..b {
        for pi in 0..p {
            for n in 0..ns {
                rows[(bi * ns + n) * p + pi] = y.data()[(bi * p + pi) * ns + n];
            }
        }
    }
    let flat = ComplexTensor::from_real(Tensor::new(&[b * ns, p], rows)?);
    let z = dft(&flat)?;
    ComplexTensor::new(
        z.re.into_reshape(&[b, ns, p])?,
        z.im.into_reshape(&[b, ns, p])?,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::naive;

    #[test]
    fn ewops_match_naive() {
        let a = Tensor::randn(&[300, 7], 1);
        let b = Tensor::randn(&[300, 7], 2);
        assert!(ewmult(&a, &b)
            .unwrap()
            .allclose(&naive::ewmult(&a, &b).unwrap(), 1e-6, 1e-6));
        assert!(ewadd(&a, &b)
            .unwrap()
            .allclose(&naive::ewadd(&a, &b).unwrap(), 1e-6, 1e-6));
    }

    #[test]
    fn ewops_parallel_path() {
        // big enough to cross PAR_THRESHOLD
        let a = Tensor::randn(&[1 << 17], 3);
        let b = Tensor::randn(&[1 << 17], 4);
        assert!(ewmult(&a, &b)
            .unwrap()
            .allclose(&naive::ewmult(&a, &b).unwrap(), 1e-6, 1e-6));
    }

    #[test]
    fn matmul_matches_naive() {
        for (m, l, n) in [(5, 7, 9), (64, 64, 64), (33, 129, 65)] {
            let a = Tensor::randn(&[m, l], 5);
            let b = Tensor::randn(&[l, n], 6);
            let got = matmul(&a, &b).unwrap();
            let want = naive::matmul(&a, &b).unwrap();
            assert!(got.allclose(&want, 1e-4, 1e-4), "({m},{l},{n})");
        }
    }

    #[test]
    fn summation_matches() {
        for n in [100usize, 1 << 17] {
            let x = Tensor::randn(&[n], 7);
            let got = summation(&x);
            let want = crate::tensor::sum(&x);
            assert!((got - want).abs() <= 1e-3 * want.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn fft_dft_match_naive_dft() {
        let x = ComplexTensor::from_real(Tensor::randn(&[2, 128], 8));
        let got = dft(&x).unwrap();
        let want = naive::dft(&x).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3));
        let back = idft(&got).unwrap();
        assert!(back.allclose(&x, 1e-3, 1e-3));
    }

    #[test]
    fn fir_unfold_match_naive() {
        let x = Tensor::randn(&[2, 700], 9);
        let taps: Vec<f32> = crate::dsp::fir_lowpass(33, 0.2).unwrap();
        assert!(fir(&x, &taps)
            .unwrap()
            .allclose(&naive::fir(&x, &taps).unwrap(), 1e-5, 1e-6));
        assert!(unfold(&x, 16)
            .unwrap()
            .allclose(&naive::unfold(&x, 16).unwrap(), 0.0, 0.0));
    }

    #[test]
    fn pfb_matches_reference() {
        let cfg = PfbConfig::new(16, 4);
        let x = Tensor::randn(&[2, 16 * 32], 10);
        let got_fir = pfb_fir(&x, cfg).unwrap();
        let want_fir = naive::pfb_fir(&x, cfg).unwrap();
        assert!(got_fir.allclose(&want_fir, 1e-4, 1e-6));
        let got = pfb(&x, cfg).unwrap();
        let want = naive::pfb(&x, cfg).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3));
    }
}
