//! Dense tensor substrate: the host-side array type every layer of the
//! rust stack (baselines, TINA interpreter, PJRT bridge) exchanges.
//!
//! Deliberately small: f32 storage, row-major contiguous, shape-checked
//! ops.  Complex data travels as (re, im) `Tensor` pairs — see
//! DESIGN.md §6.

mod ops;

pub use ops::*;

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data (length must match the shape product).
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// All-one tensor of the given shape.
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    /// Tensor filled with a constant value.
    pub fn filled(shape: &[usize], value: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![value; shape.iter().product()],
        }
    }

    /// Identity matrix (n, n).
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// Rank-0 (scalar) tensor.
    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    /// Random standard-normal tensor from a seeded generator.
    pub fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = crate::util::prng::Xoshiro256::new(seed);
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(shape.iter().product()),
        }
    }

    // -- accessors ---------------------------------------------------------

    /// Dimension extents.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major element slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, yielding its buffer.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Flat index of a multi-dimensional index (row-major).
    pub fn flat_index(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut flat = 0;
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(ix < self.shape[i], "index {ix} out of bounds {:?}", self.shape);
            flat = flat * self.shape[i] + ix;
        }
        flat
    }

    /// Element at a multi-dimensional index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.flat_index(idx)]
    }

    /// Overwrite the element at a multi-dimensional index.
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let i = self.flat_index(idx);
        self.data[i] = v;
    }

    // -- shape manipulation --------------------------------------------------

    /// Reshape into a new tensor (copies the buffer; element count must
    /// match).  Use [`Tensor::into_reshape`] to move instead of copy.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        self.clone().into_reshape(shape)
    }

    /// Reshape by moving the buffer — the zero-copy counterpart of
    /// [`Tensor::reshape`] for owned tensors (element count must match).
    pub fn into_reshape(self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!(
                "cannot reshape {:?} ({} elems) to {:?} ({} elems)",
                self.shape,
                self.data.len(),
                shape,
                n
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data: self.data,
        })
    }

    /// 2-D transpose.
    pub fn transpose2(&self) -> Result<Tensor> {
        if self.rank() != 2 {
            bail!("transpose2 needs rank 2, got {:?}", self.shape);
        }
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(&[c, r], out)
    }

    /// Permute axes of a rank-3 tensor.
    pub fn permute3(&self, perm: [usize; 3]) -> Result<Tensor> {
        if self.rank() != 3 {
            bail!("permute3 needs rank 3, got {:?}", self.shape);
        }
        let s = &self.shape;
        let out_shape = [s[perm[0]], s[perm[1]], s[perm[2]]];
        let mut out = Tensor::zeros(&out_shape);
        let mut idx = [0usize; 3];
        for i in 0..s[0] {
            for j in 0..s[1] {
                for k in 0..s[2] {
                    idx[0] = i;
                    idx[1] = j;
                    idx[2] = k;
                    let v = self.data[(i * s[1] + j) * s[2] + k];
                    let o = [idx[perm[0]], idx[perm[1]], idx[perm[2]]];
                    out.data[(o[0] * out_shape[1] + o[1]) * out_shape[2] + o[2]] = v;
                }
            }
        }
        Ok(out)
    }

    /// Concatenate tensors along an axis (all other dims must agree).
    pub fn concat(parts: &[&Tensor], axis: usize) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("concat of zero tensors");
        }
        let rank = parts[0].rank();
        if axis >= rank {
            bail!("concat axis {axis} out of range for rank {rank}");
        }
        let mut out_shape = parts[0].shape.clone();
        let mut axis_total = 0;
        for p in parts {
            if p.rank() != rank {
                bail!("concat rank mismatch");
            }
            for (d, (&a, &b)) in p.shape.iter().zip(&parts[0].shape).enumerate() {
                if d != axis && a != b {
                    bail!("concat shape mismatch at dim {d}: {a} vs {b}");
                }
            }
            axis_total += p.shape[axis];
        }
        out_shape[axis] = axis_total;

        let outer: usize = parts[0].shape[..axis].iter().product();
        let inner: usize = parts[0].shape[axis + 1..].iter().product();
        let mut data = Vec::with_capacity(out_shape.iter().product());
        for o in 0..outer {
            for p in parts {
                let rows = p.shape[axis];
                let start = o * rows * inner;
                data.extend_from_slice(&p.data[start..start + rows * inner]);
            }
        }
        Tensor::new(&out_shape, data)
    }

    /// Strided slice along an axis: keep indices 0, stride, 2*stride, ...
    /// up to `count` elements.
    pub fn stride_axis(&self, axis: usize, stride: usize, count: usize) -> Result<Tensor> {
        if axis >= self.rank() {
            bail!("stride axis {axis} out of range");
        }
        if stride == 0 {
            bail!("stride must be positive");
        }
        let extent = self.shape[axis];
        if count == 0 || (count - 1) * stride >= extent {
            bail!(
                "strided slice (stride {stride}, count {count}) exceeds axis extent {extent}"
            );
        }
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut out_shape = self.shape.clone();
        out_shape[axis] = count;
        let mut data = Vec::with_capacity(outer * count * inner);
        for o in 0..outer {
            for i in 0..count {
                let base = (o * extent + i * stride) * inner;
                data.extend_from_slice(&self.data[base..base + inner]);
            }
        }
        Tensor::new(&out_shape, data)
    }

    /// Slice along an axis: keep [start, stop).
    pub fn slice_axis(&self, axis: usize, start: usize, stop: usize) -> Result<Tensor> {
        if axis >= self.rank() {
            bail!("slice axis {axis} out of range");
        }
        if stop > self.shape[axis] || start > stop {
            bail!(
                "slice [{start}, {stop}) out of bounds for axis {axis} of {:?}",
                self.shape
            );
        }
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let rows = self.shape[axis];
        let keep = stop - start;
        let mut out_shape = self.shape.clone();
        out_shape[axis] = keep;
        let mut data = Vec::with_capacity(outer * keep * inner);
        for o in 0..outer {
            let base = (o * rows + start) * inner;
            data.extend_from_slice(&self.data[base..base + keep * inner]);
        }
        Tensor::new(&out_shape, data)
    }

    // -- comparisons ---------------------------------------------------------

    /// Maximum absolute difference (shapes must match).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!(
                "shape mismatch: {:?} vs {:?}",
                self.shape,
                other.shape
            );
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// allclose with combined absolute/relative tolerance:
    /// |a - b| <= atol + rtol * |b|.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_length() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::new(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn into_reshape_moves_without_copy() {
        let t = Tensor::new(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let ptr = t.data().as_ptr();
        let r = t.into_reshape(&[3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data().as_ptr(), ptr, "buffer must move, not copy");
        assert!(r.into_reshape(&[7]).is_err());
    }

    #[test]
    fn transpose2_roundtrip() {
        let t = Tensor::randn(&[3, 5], 1);
        let tt = t.transpose2().unwrap().transpose2().unwrap();
        assert_eq!(t, tt);
        let u = t.transpose2().unwrap();
        assert_eq!(u.shape(), &[5, 3]);
        assert_eq!(u.at(&[4, 2]), t.at(&[2, 4]));
    }

    #[test]
    fn permute3_matches_manual() {
        let t = Tensor::new(&[2, 3, 4], (0..24).map(|i| i as f32).collect()).unwrap();
        let p = t.permute3([2, 0, 1]).unwrap();
        assert_eq!(p.shape(), &[4, 2, 3]);
        for i in 0..2 {
            for j in 0..3 {
                for k in 0..4 {
                    assert_eq!(p.at(&[k, i, j]), t.at(&[i, j, k]));
                }
            }
        }
    }

    #[test]
    fn concat_axis0_and_1() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[1, 2], vec![5., 6.]).unwrap();
        let c = Tensor::concat(&[&a, &b], 0).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 3., 4., 5., 6.]);

        let d = Tensor::new(&[2, 1], vec![7., 8.]).unwrap();
        let e = Tensor::concat(&[&a, &d], 1).unwrap();
        assert_eq!(e.shape(), &[2, 3]);
        assert_eq!(e.data(), &[1., 2., 7., 3., 4., 8.]);
    }

    #[test]
    fn stride_axis_picks_every_kth() {
        let t = Tensor::new(&[1, 8], (0..8).map(|i| i as f32).collect()).unwrap();
        let s = t.stride_axis(1, 3, 3).unwrap();
        assert_eq!(s.shape(), &[1, 3]);
        assert_eq!(s.data(), &[0., 3., 6.]);
        // rank-3, middle axis
        let t = Tensor::new(&[2, 4, 2], (0..16).map(|i| i as f32).collect()).unwrap();
        let s = t.stride_axis(1, 2, 2).unwrap();
        assert_eq!(s.shape(), &[2, 2, 2]);
        assert_eq!(s.data(), &[0., 1., 4., 5., 8., 9., 12., 13.]);
        assert!(t.stride_axis(1, 2, 3).is_err()); // out of range
        assert!(t.stride_axis(1, 0, 1).is_err()); // zero stride
    }

    #[test]
    fn slice_axis_middle() {
        let t = Tensor::new(&[2, 4], (0..8).map(|i| i as f32).collect()).unwrap();
        let s = t.slice_axis(1, 1, 3).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[1., 2., 5., 6.]);
        assert!(t.slice_axis(1, 3, 5).is_err());
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::new(&[2], vec![1.0, 100.0]).unwrap();
        let b = Tensor::new(&[2], vec![1.0 + 1e-6, 100.0 + 1e-3]).unwrap();
        assert!(a.allclose(&b, 1e-4, 1e-5));
        let c = Tensor::new(&[2], vec![1.1, 100.0]).unwrap();
        assert!(!a.allclose(&c, 1e-4, 1e-5));
    }

    #[test]
    fn eye_diagonal() {
        let t = Tensor::eye(3);
        assert_eq!(t.at(&[1, 1]), 1.0);
        assert_eq!(t.at(&[1, 2]), 0.0);
    }
}
