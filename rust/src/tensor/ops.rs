//! Elementwise and linear-algebra helpers on [`Tensor`], plus the complex
//! (re, im) pair convention used for Fourier data.

use super::Tensor;
use anyhow::{bail, Result};

/// Elementwise binary op with shape checking.
fn zip_with(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
    if a.shape() != b.shape() {
        bail!("shape mismatch: {:?} vs {:?}", a.shape(), b.shape());
    }
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(&x, &y)| f(x, y))
        .collect();
    Tensor::new(a.shape(), data)
}

/// Elementwise sum (shapes must match).
pub fn add(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    zip_with(a, b, |x, y| x + y)
}

/// Elementwise difference (shapes must match).
pub fn sub(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    zip_with(a, b, |x, y| x - y)
}

/// Elementwise product (shapes must match).
pub fn mul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    zip_with(a, b, |x, y| x * y)
}

/// Multiply every element by a scalar.
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    Tensor::new(a.shape(), a.data().iter().map(|&x| x * s).collect()).unwrap()
}

/// Sum of all elements (pairwise accumulation for accuracy).
pub fn sum(a: &Tensor) -> f32 {
    // pairwise-ish summation for accuracy on long vectors
    fn rec(xs: &[f32]) -> f64 {
        if xs.len() <= 64 {
            return xs.iter().map(|&x| x as f64).sum();
        }
        let mid = xs.len() / 2;
        rec(&xs[..mid]) + rec(&xs[mid..])
    }
    rec(a.data()) as f32
}

/// Naive (M,L)x(L,N) matmul — the numerically-trustworthy reference the
/// optimized/baseline implementations and PJRT outputs are checked against.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.rank() != 2 || b.rank() != 2 {
        bail!("matmul needs rank-2 operands");
    }
    let (m, l) = (a.shape()[0], a.shape()[1]);
    let (l2, n) = (b.shape()[0], b.shape()[1]);
    if l != l2 {
        bail!("matmul contraction mismatch: {l} vs {l2}");
    }
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for k in 0..l {
            let aik = a.data()[i * l + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data()[k * n..(k + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    Tensor::new(&[m, n], out)
}

/// A complex tensor as (re, im) pair — the ABI Fourier artifacts use.
#[derive(Debug, Clone, PartialEq)]
pub struct ComplexTensor {
    /// Real part.
    pub re: Tensor,
    /// Imaginary part.
    pub im: Tensor,
}

impl ComplexTensor {
    /// Pair up real and imaginary parts (shapes must match).
    pub fn new(re: Tensor, im: Tensor) -> Result<ComplexTensor> {
        if re.shape() != im.shape() {
            bail!(
                "complex pair shape mismatch: {:?} vs {:?}",
                re.shape(),
                im.shape()
            );
        }
        Ok(ComplexTensor { re, im })
    }

    /// Complex tensor with zero imaginary part.
    pub fn from_real(re: Tensor) -> ComplexTensor {
        let im = Tensor::zeros(re.shape());
        ComplexTensor { re, im }
    }

    /// Shared shape of both parts.
    pub fn shape(&self) -> &[usize] {
        self.re.shape()
    }

    /// Elementwise |z|^2 (the power spectrum used by the spectrometer
    /// example).
    pub fn power(&self) -> Tensor {
        let data = self
            .re
            .data()
            .iter()
            .zip(self.im.data())
            .map(|(&r, &i)| r * r + i * i)
            .collect();
        Tensor::new(self.re.shape(), data).unwrap()
    }

    /// Approximate equality of both parts.
    pub fn allclose(&self, other: &ComplexTensor, rtol: f32, atol: f32) -> bool {
        self.re.allclose(&other.re, rtol, atol) && self.im.allclose(&other.im, rtol, atol)
    }

    /// Complex matmul via four real matmuls (mirrors the TINA mapping).
    pub fn matmul(&self, k: &ComplexTensor) -> Result<ComplexTensor> {
        let rr = matmul(&self.re, &k.re)?;
        let ii = matmul(&self.im, &k.im)?;
        let ri = matmul(&self.re, &k.im)?;
        let ir = matmul(&self.im, &k.re)?;
        Ok(ComplexTensor {
            re: sub(&rr, &ii)?,
            im: add(&ri, &ir)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        assert_eq!(add(&a, &b).unwrap().data(), &[6., 8., 10., 12.]);
        assert_eq!(mul(&a, &b).unwrap().data(), &[5., 12., 21., 32.]);
        assert_eq!(sub(&b, &a).unwrap().data(), &[4., 4., 4., 4.]);
        assert!(add(&a, &Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn sum_accuracy_on_long_vector() {
        // 1M values of 0.1 — naive f32 running sum drifts noticeably;
        // pairwise keeps it tight.
        let t = Tensor::filled(&[1_000_000], 0.1);
        let s = sum(&t);
        assert!((s - 100_000.0).abs() < 0.5, "sum={s}");
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::randn(&[4, 4], 9);
        let i = Tensor::eye(4);
        assert!(matmul(&a, &i).unwrap().allclose(&a, 1e-6, 1e-6));
        assert!(matmul(&i, &a).unwrap().allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn complex_matmul_against_manual() {
        // (1 + 2i) * (3 + 4i) = 3 + 4i + 6i - 8 = -5 + 10i
        let a = ComplexTensor::new(
            Tensor::new(&[1, 1], vec![1.]).unwrap(),
            Tensor::new(&[1, 1], vec![2.]).unwrap(),
        )
        .unwrap();
        let b = ComplexTensor::new(
            Tensor::new(&[1, 1], vec![3.]).unwrap(),
            Tensor::new(&[1, 1], vec![4.]).unwrap(),
        )
        .unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.re.data(), &[-5.]);
        assert_eq!(c.im.data(), &[10.]);
    }

    #[test]
    fn power_spectrum() {
        let z = ComplexTensor::new(
            Tensor::new(&[2], vec![3., 0.]).unwrap(),
            Tensor::new(&[2], vec![4., 2.]).unwrap(),
        )
        .unwrap();
        assert_eq!(z.power().data(), &[25., 4.]);
    }
}
