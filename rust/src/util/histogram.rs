//! Log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Buckets are base-2 with 8 linear sub-buckets each, giving <= ~9% relative
//! quantile error over a 1ns..1000s range — plenty for serving metrics.

const SUB_BUCKETS: usize = 8;
const BUCKETS: usize = 64;

/// Log-bucketed value histogram over nanosecond durations.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: u128,
    max_ns: u64,
    min_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS * SUB_BUCKETS],
            total: 0,
            sum_ns: 0,
            max_ns: 0,
            min_ns: u64::MAX,
        }
    }

    fn index(value: u64) -> usize {
        if value < SUB_BUCKETS as u64 {
            return value as usize;
        }
        let msb = 63 - value.leading_zeros() as usize;
        let shift = msb - 3; // SUB_BUCKETS = 2^3
        let sub = ((value >> shift) & 0b111) as usize;
        let bucket = shift + 1;
        (bucket * SUB_BUCKETS + sub).min(BUCKETS * SUB_BUCKETS - 1)
    }

    /// Representative (lower-bound) value for a slot index.
    fn slot_value(idx: usize) -> u64 {
        let bucket = idx / SUB_BUCKETS;
        let sub = idx % SUB_BUCKETS;
        if bucket == 0 {
            return sub as u64;
        }
        let _shift = bucket - 1; // inverse of index()
        ((SUB_BUCKETS + sub) as u64) << (bucket - 1)
    }

    /// Record one nanosecond value.
    pub fn record(&mut self, value_ns: u64) {
        self.counts[Self::index(value_ns)] += 1;
        self.total += 1;
        self.sum_ns += value_ns as u128;
        self.max_ns = self.max_ns.max(value_ns);
        self.min_ns = self.min_ns.min(value_ns);
    }

    /// Record a duration (saturating at u64 nanoseconds).
    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.total as f64
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max_ns(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.max_ns
        }
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min_ns(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min_ns
        }
    }

    /// Quantile in [0, 1] -> approximate value (lower bound of the slot).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::slot_value(i);
            }
        }
        self.max_ns
    }

    /// Median (approximate, slot lower bound).
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 95th percentile (approximate, slot lower bound).
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    /// 99th percentile (approximate, slot lower bound).
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Fold another histogram's counts into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
    }

    /// Render a one-line summary, durations in human units.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.total,
            fmt_ns(self.mean_ns() as u64),
            fmt_ns(self.p50()),
            fmt_ns(self.p95()),
            fmt_ns(self.p99()),
            fmt_ns(self.max_ns()),
        )
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_monotonic_in_value() {
        let mut last = 0;
        for v in 0..100_000u64 {
            let idx = Histogram::index(v);
            assert!(idx >= last, "index must be monotonic at {v}");
            last = idx;
        }
    }

    #[test]
    fn slot_value_is_lower_bound() {
        for v in [0u64, 1, 7, 8, 9, 100, 1000, 123_456, 88_888_888] {
            let idx = Histogram::index(v);
            let lo = Histogram::slot_value(idx);
            assert!(lo <= v, "slot lower bound {lo} > value {v}");
            // relative error of the bound is < 1/8 + epsilon
            if v > 8 {
                assert!((v - lo) as f64 / v as f64 <= 0.125 + 1e-9, "v={v} lo={lo}");
            }
        }
    }

    #[test]
    fn quantiles_of_uniform_ramp() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000); // 1us..10ms ramp
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.15, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.15, "p99={p99}");
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 0..1000u64 {
            a.record(v * 7);
            c.record(v * 7);
        }
        for v in 0..500u64 {
            b.record(v * 131);
            c.record(v * 131);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.p50(), c.p50());
        assert_eq!(a.p99(), c.p99());
        assert_eq!(a.max_ns(), c.max_ns());
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12), "12ns");
        assert_eq!(fmt_ns(12_340), "12.34us");
        assert_eq!(fmt_ns(12_340_000), "12.34ms");
        assert_eq!(fmt_ns(1_500_000_000), "1.50s");
    }
}
