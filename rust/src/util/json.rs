//! Minimal JSON parser and writer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar minus some escape exotica; covers what
//! the artifact manifest, the TCP protocol and the bench reports need.
//! Numbers are stored as f64 (like JavaScript); the manifest never holds
//! integers that lose precision below 2^53.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are sorted (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with a byte offset into the input.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset of the failure in the input.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Number from anything convertible to f64.
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- accessors ---------------------------------------------------------
    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns Null-typed None for missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    // -- serialization -----------------------------------------------------
    /// Render as compact JSON text (stable key order).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/inf literal; `format!("{x}")` would
                    // emit bare `NaN`/`inf` that no parser accepts.  null
                    // is the standard lossy encoding (what JavaScript's
                    // JSON.stringify does); callers that must round-trip
                    // non-finite values use the binary wire protocol.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // no surrogate-pair support (manifest never needs it)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#" {"a": [1, 2, {"b": null}], "c": "x", "d": {"e": [true]}} "#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(
            v.get("d").unwrap().get("e").unwrap().as_arr().unwrap()[0],
            Json::Bool(true)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nulL").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn roundtrip_through_writer() {
        let doc = r#"{"arr":[1,2.5,"s",null,true],"num":-7,"obj":{"k":"v"}}"#;
        let v = parse(doc).unwrap();
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
        assert_eq!(s, doc); // keys sorted + canonical numbers -> stable text
    }

    #[test]
    fn non_finite_nums_serialize_as_null() {
        // regression: these used to emit bare `NaN` / `inf` / `-inf`,
        // invalid JSON no parser (including our own) accepts
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(x).to_string();
            assert_eq!(s, "null", "non-finite {x} must serialize as null");
            assert_eq!(parse(&s).unwrap(), Json::Null);
        }
        let doc = Json::obj(vec![("a", Json::Num(f64::NAN)), ("b", Json::num(1.5))]);
        let s = doc.to_string();
        assert_eq!(s, r#"{"a":null,"b":1.5}"#);
        assert!(parse(&s).is_ok(), "writer output must stay parseable");
    }

    #[test]
    fn integer_boundary_values_roundtrip() {
        // ±9e15 sits at the i64-formatting cutoff in the writer; both
        // sides of the boundary must round-trip through parse()
        for x in [
            9.0e15 - 1.0,
            9.0e15,
            9.0e15 + 2.0,
            -(9.0e15 - 1.0),
            -9.0e15,
            -(9.0e15 + 2.0),
            0.0,
            -0.5,
        ] {
            let s = Json::Num(x).to_string();
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "{x} -> {s} -> {back}");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("quote\" slash\\ nl\n tab\t ctrl\u{1}");
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(parse("17").unwrap().as_usize(), Some(17));
        assert_eq!(parse("-1").unwrap().as_usize(), None);
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
    }
}
