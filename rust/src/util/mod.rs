//! Infrastructure substrates implemented in-repo.
//!
//! The build environment is offline with only the `xla` crate's dependency
//! closure cached, so the usual ecosystem crates (serde_json, clap, rand,
//! half, tokio, criterion, proptest) are unavailable.  Each submodule here
//! is a small, tested, from-scratch replacement for exactly the slice of
//! functionality this project needs — see DESIGN.md §6.

pub mod bf16;
pub mod cli;
pub mod histogram;
pub mod json;
pub mod prng;
pub mod threadpool;
