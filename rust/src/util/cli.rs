//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Model: `tina <subcommand> [--flag] [--key value] [positional...]`.
//! Long options only; `--key=value` and `--key value` both accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare word (the subcommand), if any.
    pub subcommand: Option<String>,
    /// Bare words after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Whether a bare `--name` switch was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if given.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or a default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// The value of `--name` parsed as an integer, or a default.
    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    /// The value of `--name` parsed as a float, or a default.
    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["run", "fir_tina_f32_B1_L4096", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["fir_tina_f32_B1_L4096", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["serve", "--port", "7070", "--artifacts=../artifacts"]);
        assert_eq!(a.opt("port"), Some("7070"));
        assert_eq!(a.opt("artifacts"), Some("../artifacts"));
        assert_eq!(a.opt_usize("port", 0).unwrap(), 7070);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["bench", "--verbose", "--iters", "10", "--json"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("json"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.opt_usize("iters", 1).unwrap(), 10);
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse(&["x", "--dry-run"]);
        assert!(a.flag("dry-run"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--iters", "ten"]);
        assert!(a.opt_usize("iters", 1).is_err());
    }
}
