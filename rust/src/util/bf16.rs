//! Minimal bfloat16 support (the `half` crate is unavailable offline).
//!
//! bf16 is f32 with the bottom 16 mantissa bits dropped; conversion is a
//! shift plus round-to-nearest-even, matching what the MXU (and the XLA
//! `bf16` type the TINA-16 artifacts compute in) does.

/// Convert f32 -> bf16 bit pattern with round-to-nearest-even.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserve sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round to nearest even: add 0x7FFF + lsb of the kept part
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + lsb)) >> 16) as u16
}

/// Convert bf16 bit pattern -> f32 (exact).
pub fn bf16_bits_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// Round-trip an f32 through bf16 precision (what a bf16 compute graph
/// does to its inputs).  Useful for tolerance modelling in tests.
pub fn quantize_bf16(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// Quantize a whole slice in place.
pub fn quantize_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = quantize_bf16(*x);
    }
}

/// Max relative error introduced by one bf16 rounding (2^-8).
pub const BF16_EPS: f32 = 1.0 / 256.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, -65280.0] {
            assert_eq!(quantize_bf16(x), x, "{x} should be bf16-exact");
        }
    }

    #[test]
    fn rounding_is_nearest() {
        // bf16 has a 7-bit mantissa: the ulp at 1.0 is 2^-7.  1.0 + 2^-8 is
        // exactly halfway; nearest-even rounds down to 1.0.
        let x = 1.0f32 + f32::powi(2.0, -8);
        assert_eq!(quantize_bf16(x), 1.0);
        // slightly above halfway rounds up to 1 + 2^-7
        let y = 1.0f32 + f32::powi(2.0, -8) + f32::powi(2.0, -11);
        assert_eq!(quantize_bf16(y), 1.0 + f32::powi(2.0, -7));
        // and halfway at an odd mantissa rounds up (to even)
        let z = 1.0f32 + f32::powi(2.0, -7) + f32::powi(2.0, -8);
        assert_eq!(quantize_bf16(z), 1.0 + 2.0 * f32::powi(2.0, -7));
    }

    #[test]
    fn relative_error_bounded() {
        let mut g = crate::util::prng::Xoshiro256::new(5);
        for _ in 0..10_000 {
            let x = g.uniform(-1e6, 1e6);
            let q = quantize_bf16(x);
            if x != 0.0 {
                assert!(((q - x) / x).abs() <= BF16_EPS, "x={x} q={q}");
            }
        }
    }

    #[test]
    fn nan_and_inf_preserved() {
        assert!(quantize_bf16(f32::NAN).is_nan());
        assert_eq!(quantize_bf16(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize_bf16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }
}
