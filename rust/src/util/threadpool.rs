//! Fixed-size worker pool with bounded queues (tokio/rayon are unavailable
//! offline).  Powers the coordinator's scheduler and the optimized CPU
//! baseline's data-parallel loops.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    job_ready: Condvar,
    slot_free: Condvar,
    capacity: usize,
    shutdown: AtomicBool,
}

/// A fixed pool of worker threads consuming a bounded FIFO of jobs.
///
/// `submit` blocks when the queue is full — this is the backpressure
/// mechanism the coordinator leans on (DESIGN.md §4).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers sharing a queue of `queue_capacity` slots.
    pub fn new(threads: usize, queue_capacity: usize) -> Self {
        assert!(threads > 0, "need at least one worker");
        assert!(queue_capacity > 0, "need a positive queue capacity");
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            job_ready: Condvar::new(),
            slot_free: Condvar::new(),
            capacity: queue_capacity,
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tina-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job, blocking while the queue is at capacity.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        let mut q = self.shared.queue.lock().unwrap();
        while q.len() >= self.shared.capacity {
            q = self.shared.slot_free.wait(q).unwrap();
        }
        q.push_back(Box::new(job));
        drop(q);
        self.shared.job_ready.notify_one();
    }

    /// Try to enqueue without blocking; returns false if the queue is full.
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, job: F) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.shared.capacity {
            return false;
        }
        q.push_back(Box::new(job));
        drop(q);
        self.shared.job_ready.notify_one();
        true
    }

}

/// Data-parallel index loop over scoped threads (the rayon substitute used
/// by the optimized CPU baseline).  Splits [0, n) into `threads` contiguous
/// chunks; `f` must be safe to call concurrently on disjoint indices.
///
/// Scoped threads make this safe without 'static bounds; spawn overhead is
/// tens of microseconds, so callers only parallelize work that is much
/// larger than that (the baseline gates on a size threshold).
pub fn parallel_for(threads: usize, n: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return;
    }
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let stop = ((t + 1) * chunk).min(n);
            if start >= stop {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, stop));
        }
    });
}

/// Send-able raw pointer wrapper for disjoint parallel writes from
/// [`parallel_for`] workers.  The accessor takes `self` so closures
/// capture the whole wrapper (edition-2021 disjoint capture would
/// otherwise capture the bare `*mut f32`).  Callers guarantee every
/// thread writes a disjoint index range.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f32);
// SAFETY: SendPtr wraps the base pointer of a `&mut [f32]` that outlives
// the scoped-thread region it is shared with; every user derives disjoint
// per-thread subranges from it (documented `// SAFETY:` at each use), so
// moving the pointer across threads introduces no aliased mutation.
unsafe impl Send for SendPtr {}
// SAFETY: same invariant as Send — the wrapper is only ever used to carve
// disjoint write ranges, so shared references to it are harmless.
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Pointer offset; callers guarantee disjoint ranges across threads.
    pub(crate) fn at(self, offset: usize) -> *mut f32 {
        // SAFETY: callers only request offsets inside the allocation the
        // wrapped base pointer was derived from (the destination slice),
        // so the resulting pointer stays in bounds.
        unsafe { self.0.add(offset) }
    }
}

/// Default worker count: physical parallelism minus one for the
/// coordinator thread, at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    shared.slot_free.notify_one();
                    break job;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.job_ready.wait(q).unwrap();
            }
        };
        job();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.job_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A one-shot channel for returning results from submitted jobs.
pub struct OneShot<T> {
    inner: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> Clone for OneShot<T> {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OneShot<T> {
    /// Empty slot; clones share the same cell.
    pub fn new() -> Self {
        Self {
            inner: Arc::new((Mutex::new(None), Condvar::new())),
        }
    }

    /// Fill the slot and wake all waiters.
    pub fn set(&self, value: T) {
        let (lock, cv) = &*self.inner;
        *lock.lock().unwrap() = Some(value);
        cv.notify_all();
    }

    /// Block until the slot is filled, then take the value.
    pub fn wait(&self) -> T {
        let (lock, cv) = &*self.inner;
        let mut slot = lock.lock().unwrap();
        loop {
            if let Some(v) = slot.take() {
                return v;
            }
            slot = cv.wait(slot).unwrap();
        }
    }

    /// Take the value if already set, without blocking.
    pub fn try_take(&self) -> Option<T> {
        self.inner.0.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicUsize::new(0));
        let results: Vec<OneShot<()>> = (0..100).map(|_| OneShot::new()).collect();
        for r in &results {
            let counter = Arc::clone(&counter);
            let r = r.clone();
            pool.submit(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                r.set(());
            });
        }
        for r in &results {
            r.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn backpressure_try_submit() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        // block the single worker
        pool.submit(move || {
            let (lock, cv) = &*g2;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        // fill the queue (eventually try_submit must fail)
        let mut accepted = 0;
        for _ in 0..64 {
            if pool.try_submit(|| {}) {
                accepted += 1;
            }
        }
        assert!(accepted < 64, "queue should saturate");
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    #[test]
    fn parallel_for_touches_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(4, 1000, |start, stop| {
            for i in start..stop {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_handles_edge_counts() {
        for n in [0usize, 1, 2, 3, 7] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            parallel_for(8, n, |start, stop| {
                for i in start..stop {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "n={n}");
        }
    }

    #[test]
    fn oneshot_roundtrip() {
        let c = OneShot::new();
        let c2 = c.clone();
        std::thread::spawn(move || c2.set(123u32));
        assert_eq!(c.wait(), 123);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang; pending jobs drained by workers or dropped
    }
}
