//! Fixed-size worker pools with bounded queues (tokio/rayon are unavailable
//! offline).  [`ThreadPool`] powers the coordinator's direct-path scheduler
//! and the optimized CPU baseline's data-parallel loops; [`ExecPool`] is the
//! fault-contained batch execution pool — named workers, `catch_unwind`
//! panic isolation, bounded submit, and a deadline-bounded drain on
//! shutdown.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    job_ready: Condvar,
    slot_free: Condvar,
    capacity: usize,
    shutdown: AtomicBool,
    panics: AtomicU64,
}

/// A fixed pool of worker threads consuming a bounded FIFO of jobs.
///
/// `submit` blocks when the queue is full — this is the backpressure
/// mechanism the coordinator leans on (DESIGN.md §4).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers sharing a queue of `queue_capacity` slots.
    pub fn new(threads: usize, queue_capacity: usize) -> Self {
        assert!(threads > 0, "need at least one worker");
        assert!(queue_capacity > 0, "need a positive queue capacity");
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            job_ready: Condvar::new(),
            slot_free: Condvar::new(),
            capacity: queue_capacity,
            shutdown: AtomicBool::new(false),
            panics: AtomicU64::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tina-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job, blocking while the queue is at capacity.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        let mut q = self.shared.queue.lock().unwrap();
        while q.len() >= self.shared.capacity {
            q = self.shared.slot_free.wait(q).unwrap();
        }
        q.push_back(Box::new(job));
        drop(q);
        self.shared.job_ready.notify_one();
    }

    /// Try to enqueue without blocking; returns false if the queue is full.
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, job: F) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.shared.capacity {
            return false;
        }
        q.push_back(Box::new(job));
        drop(q);
        self.shared.job_ready.notify_one();
        true
    }

    /// Number of submitted jobs that panicked (contained; the worker
    /// survives and keeps draining the queue).
    pub fn panics(&self) -> u64 {
        self.shared.panics.load(Ordering::Relaxed)
    }
}

/// Data-parallel index loop over scoped threads (the rayon substitute used
/// by the optimized CPU baseline).  Splits [0, n) into `threads` contiguous
/// chunks; `f` must be safe to call concurrently on disjoint indices.
///
/// Scoped threads make this safe without 'static bounds; spawn overhead is
/// tens of microseconds, so callers only parallelize work that is much
/// larger than that (the baseline gates on a size threshold).
pub fn parallel_for(threads: usize, n: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return;
    }
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let stop = ((t + 1) * chunk).min(n);
            if start >= stop {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, stop));
        }
    });
}

/// Send-able raw pointer wrapper for disjoint parallel writes from
/// [`parallel_for`] workers.  The accessor takes `self` so closures
/// capture the whole wrapper (edition-2021 disjoint capture would
/// otherwise capture the bare `*mut f32`).  Callers guarantee every
/// thread writes a disjoint index range.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f32);
// SAFETY: SendPtr wraps the base pointer of a `&mut [f32]` that outlives
// the scoped-thread region it is shared with; every user derives disjoint
// per-thread subranges from it (documented `// SAFETY:` at each use), so
// moving the pointer across threads introduces no aliased mutation.
unsafe impl Send for SendPtr {}
// SAFETY: same invariant as Send — the wrapper is only ever used to carve
// disjoint write ranges, so shared references to it are harmless.
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Pointer offset; callers guarantee disjoint ranges across threads.
    pub(crate) fn at(self, offset: usize) -> *mut f32 {
        // SAFETY: callers only request offsets inside the allocation the
        // wrapped base pointer was derived from (the destination slice),
        // so the resulting pointer stays in bounds.
        unsafe { self.0.add(offset) }
    }
}

/// Default worker count: physical parallelism minus one for the
/// coordinator thread, at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    shared.slot_free.notify_one();
                    break job;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.job_ready.wait(q).unwrap();
            }
        };
        // Panic containment: a panicking job must not kill the worker —
        // under the old bare `job()` a single panic permanently shrank
        // the pool.  Unwind safety is asserted because a job's captured
        // state dies with the job (`Completion::drop` fails its waiters).
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            shared.panics.fetch_add(1, Ordering::Relaxed);
            eprintln!("tina: pool job panicked (contained; worker continues)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.job_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// ExecPool: fault-contained batch execution
// ---------------------------------------------------------------------------

struct ExecState {
    queue: VecDeque<Job>,
    live_workers: usize,
}

struct ExecShared {
    state: Mutex<ExecState>,
    job_ready: Condvar,
    slot_free: Condvar,
    worker_done: Condvar,
    capacity: usize,
    /// No new submissions accepted (set by [`ExecPool::close`]).
    closed: AtomicBool,
    /// Workers exit once the queue is empty (set by `shutdown_join`).
    stopping: AtomicBool,
    panics: AtomicU64,
}

/// Bounded, named execution pool for batch jobs — the replacement for the
/// old detached `spawn_batch_exec` per-batch threads.
///
/// Fault-containment properties:
///
/// * **Panic isolation.** Workers run each job under `catch_unwind`; a
///   panicking kernel fails only its own batch (dropping the job's
///   captured `Completion`s errors every waiter) and the worker survives.
/// * **Bounded admission.** [`submit_timeout`](Self::submit_timeout)
///   refuses (returns `false`, dropping the job → waiters error) instead
///   of blocking forever when the queue stays full past the deadline, so
///   a wedged pool turns into fast failures, not a spawn storm or a hang.
/// * **Bounded drain.** [`shutdown_join`](Self::shutdown_join) drops
///   queued jobs (failing their waiters immediately), waits for in-flight
///   jobs up to a deadline, then *detaches* stragglers — a stuck kernel
///   cannot wedge coordinator shutdown.
pub struct ExecPool {
    shared: Arc<ExecShared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    threads: usize,
}

impl ExecPool {
    /// Spawn `threads` workers (named `tina-exec-{i}`) sharing a bounded
    /// queue of `queue_capacity` job slots.  Both are clamped to ≥ 1.
    pub fn new(threads: usize, queue_capacity: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(ExecShared {
            state: Mutex::new(ExecState {
                queue: VecDeque::new(),
                live_workers: threads,
            }),
            job_ready: Condvar::new(),
            slot_free: Condvar::new(),
            worker_done: Condvar::new(),
            capacity: queue_capacity.max(1),
            closed: AtomicBool::new(false),
            stopping: AtomicBool::new(false),
            panics: AtomicU64::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tina-exec-{i}"))
                    .spawn(move || exec_worker_loop(shared))
                    .expect("spawn exec worker")
            })
            .collect();
        Self {
            shared,
            workers: Mutex::new(workers),
            threads,
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enqueue a job, waiting at most `timeout` for a queue slot.
    ///
    /// Returns `false` — dropping `job`, which fails any `Completion`s it
    /// captured — when the pool is closed, the fault site
    /// `exec_pool.submit` refuses, or no slot frees up within the
    /// deadline.  Never blocks past `timeout`.
    pub fn submit_timeout(&self, job: impl FnOnce() + Send + 'static, timeout: Duration) -> bool {
        if crate::testing::faults::refused("exec_pool.submit") {
            return false;
        }
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if self.shared.closed.load(Ordering::Acquire) {
                return false;
            }
            if st.queue.len() < self.shared.capacity {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            st = self
                .shared
                .slot_free
                .wait_timeout(st, deadline - now)
                .unwrap()
                .0;
        }
        st.queue.push_back(Box::new(job));
        drop(st);
        self.shared.job_ready.notify_one();
        true
    }

    /// Stop accepting new jobs and wake any blocked submitters (they
    /// refuse).  In-flight and already-queued jobs still execute.
    pub fn close(&self) {
        self.shared.closed.store(true, Ordering::Release);
        self.shared.slot_free.notify_all();
    }

    /// Bounded drain: close the pool, drop still-queued jobs (failing
    /// their waiters immediately), and wait up to `deadline` for in-flight
    /// jobs to finish.  Returns `true` if every worker exited in time;
    /// stragglers (e.g. a stuck kernel) are detached so shutdown cannot
    /// wedge.  Idempotent.
    pub fn shutdown_join(&self, deadline: Duration) -> bool {
        self.close();
        self.shared.stopping.store(true, Ordering::Release);
        let dropped: Vec<Job> = {
            let mut st = self.shared.state.lock().unwrap();
            st.queue.drain(..).collect()
        };
        // dropping outside the lock: each job's Completions fail here
        drop(dropped);
        self.shared.job_ready.notify_all();
        let limit = Instant::now() + deadline;
        let mut st = self.shared.state.lock().unwrap();
        while st.live_workers > 0 {
            let now = Instant::now();
            if now >= limit {
                break;
            }
            st = self
                .shared
                .worker_done
                .wait_timeout(st, limit - now)
                .unwrap()
                .0;
        }
        let drained = st.live_workers == 0;
        drop(st);
        let mut workers = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        if drained {
            for w in workers.drain(..) {
                let _ = w.join();
            }
        } else {
            workers.clear(); // detach stragglers
        }
        drained
    }

    /// Number of jobs that panicked (contained; workers survive).  This
    /// is the pool-level backstop counter — the coordinator's
    /// `exec_panics` metric counts at the batch layer.
    pub fn panics(&self) -> u64 {
        self.shared.panics.load(Ordering::Relaxed)
    }
}

fn exec_worker_loop(shared: Arc<ExecShared>) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    shared.slot_free.notify_one();
                    break Some(job);
                }
                if shared.stopping.load(Ordering::Acquire) {
                    break None;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        let Some(job) = job else { break };
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            shared.panics.fetch_add(1, Ordering::Relaxed);
            eprintln!("tina: exec-pool job panicked (contained; pool continues)");
        }
    }
    let mut st = shared.state.lock().unwrap();
    st.live_workers -= 1;
    drop(st);
    shared.worker_done.notify_all();
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        let live = !self
            .workers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .is_empty();
        if live {
            self.shutdown_join(Duration::from_secs(5));
        }
    }
}

/// A one-shot channel for returning results from submitted jobs.
pub struct OneShot<T> {
    inner: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> Clone for OneShot<T> {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OneShot<T> {
    /// Empty slot; clones share the same cell.
    pub fn new() -> Self {
        Self {
            inner: Arc::new((Mutex::new(None), Condvar::new())),
        }
    }

    /// Fill the slot and wake all waiters.
    pub fn set(&self, value: T) {
        let (lock, cv) = &*self.inner;
        *lock.lock().unwrap() = Some(value);
        cv.notify_all();
    }

    /// Block until the slot is filled, then take the value.
    pub fn wait(&self) -> T {
        let (lock, cv) = &*self.inner;
        let mut slot = lock.lock().unwrap();
        loop {
            if let Some(v) = slot.take() {
                return v;
            }
            slot = cv.wait(slot).unwrap();
        }
    }

    /// Block until the slot is filled or `timeout` elapses; `None` on
    /// timeout.  The chaos tests use this to prove no waiter ever hangs.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<T> {
        let (lock, cv) = &*self.inner;
        let deadline = Instant::now() + timeout;
        let mut slot = lock.lock().unwrap();
        loop {
            if let Some(v) = slot.take() {
                return Some(v);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            slot = cv.wait_timeout(slot, deadline - now).unwrap().0;
        }
    }

    /// Take the value if already set, without blocking.
    pub fn try_take(&self) -> Option<T> {
        self.inner.0.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicUsize::new(0));
        let results: Vec<OneShot<()>> = (0..100).map(|_| OneShot::new()).collect();
        for r in &results {
            let counter = Arc::clone(&counter);
            let r = r.clone();
            pool.submit(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                r.set(());
            });
        }
        for r in &results {
            r.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn backpressure_try_submit() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        // block the single worker
        pool.submit(move || {
            let (lock, cv) = &*g2;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        // fill the queue (eventually try_submit must fail)
        let mut accepted = 0;
        for _ in 0..64 {
            if pool.try_submit(|| {}) {
                accepted += 1;
            }
        }
        assert!(accepted < 64, "queue should saturate");
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    #[test]
    fn parallel_for_touches_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(4, 1000, |start, stop| {
            for i in start..stop {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_handles_edge_counts() {
        for n in [0usize, 1, 2, 3, 7] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            parallel_for(8, n, |start, stop| {
                for i in start..stop {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "n={n}");
        }
    }

    #[test]
    fn oneshot_roundtrip() {
        let c = OneShot::new();
        let c2 = c.clone();
        std::thread::spawn(move || c2.set(123u32));
        assert_eq!(c.wait(), 123);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang; pending jobs drained by workers or dropped
    }

    #[test]
    fn worker_survives_a_panicking_job() {
        let pool = ThreadPool::new(1, 8);
        pool.submit(|| panic!("boom"));
        let done = OneShot::new();
        let d2 = done.clone();
        pool.submit(move || d2.set(7u32));
        assert_eq!(
            done.wait_timeout(Duration::from_secs(10)),
            Some(7),
            "the single worker must survive the preceding panic"
        );
        assert_eq!(pool.panics(), 1);
    }

    #[test]
    fn exec_pool_runs_jobs_and_contains_panics() {
        let pool = ExecPool::new(2, 4);
        assert_eq!(pool.threads(), 2);
        pool.submit_timeout(|| panic!("kernel fault"), Duration::from_secs(1));
        let results: Vec<OneShot<usize>> = (0..8).map(|_| OneShot::new()).collect();
        for (i, r) in results.iter().enumerate() {
            let r = r.clone();
            assert!(pool.submit_timeout(move || r.set(i), Duration::from_secs(10)));
        }
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.wait_timeout(Duration::from_secs(10)), Some(i));
        }
        assert_eq!(pool.panics(), 1, "panic contained, pool kept serving");
        assert!(pool.shutdown_join(Duration::from_secs(5)));
    }

    #[test]
    fn exec_pool_submit_times_out_instead_of_blocking() {
        let pool = ExecPool::new(1, 1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        // wedge the single worker
        assert!(pool.submit_timeout(
            move || {
                let (lock, cv) = &*g2;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            },
            Duration::from_secs(1),
        ));
        // fill the single queue slot (worker may have taken the first job)
        let mut filled = 0;
        while pool.submit_timeout(|| {}, Duration::from_millis(50)) {
            filled += 1;
            assert!(filled <= 2, "bounded queue must saturate");
        }
        // a saturated pool refuses within the deadline — and dropping the
        // refused job must fail its waiter rather than hang it
        let dropped = OneShot::new();
        let d2 = dropped.clone();
        struct FailOnDrop(OneShot<&'static str>);
        impl Drop for FailOnDrop {
            fn drop(&mut self) {
                self.0.set("dropped");
            }
        }
        let sentinel = FailOnDrop(d2);
        let t0 = Instant::now();
        assert!(!pool.submit_timeout(
            move || {
                let _keep = &sentinel;
            },
            Duration::from_millis(50)
        ));
        assert!(t0.elapsed() < Duration::from_secs(5), "refusal must be fast");
        assert_eq!(dropped.wait_timeout(Duration::from_secs(5)), Some("dropped"));
        // un-wedge so shutdown drains cleanly
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        assert!(pool.shutdown_join(Duration::from_secs(10)));
    }

    #[test]
    fn exec_pool_shutdown_drops_queued_jobs_and_detaches_stragglers() {
        let pool = ExecPool::new(1, 4);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        assert!(pool.submit_timeout(
            move || {
                let (lock, cv) = &*g2;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            },
            Duration::from_secs(1),
        ));
        let ran = Arc::new(AtomicUsize::new(0));
        let queued_dropped = OneShot::new();
        {
            let ran = Arc::clone(&ran);
            let q2 = queued_dropped.clone();
            struct Sentinel(OneShot<()>);
            impl Drop for Sentinel {
                fn drop(&mut self) {
                    self.0.set(());
                }
            }
            let s = Sentinel(q2);
            assert!(pool.submit_timeout(
                move || {
                    let _keep = &s;
                    ran.fetch_add(1, Ordering::SeqCst);
                },
                Duration::from_secs(1),
            ));
        }
        // the worker is wedged: shutdown must still return promptly,
        // reporting an un-drained straggler, and the queued job must be
        // dropped (its sentinel fires) rather than executed
        let t0 = Instant::now();
        assert!(!pool.shutdown_join(Duration::from_millis(200)));
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(queued_dropped.wait_timeout(Duration::from_secs(5)), Some(()));
        assert_eq!(ran.load(Ordering::SeqCst), 0, "dropped job must not run");
        // second call is idempotent; release the straggler afterwards
        assert!(!pool.shutdown_join(Duration::from_millis(50)));
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    #[test]
    fn exec_pool_close_refuses_new_work() {
        let pool = ExecPool::new(1, 4);
        pool.close();
        assert!(!pool.submit_timeout(|| {}, Duration::from_millis(50)));
        assert!(pool.shutdown_join(Duration::from_secs(5)));
    }
}
