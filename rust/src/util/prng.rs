//! Deterministic PRNGs for workload generation and property testing.
//!
//! SplitMix64 (seeding / streams) and xoshiro256** (bulk generation) — the
//! same generators NumPy and the JVM ship; both are reproducible across
//! platforms, which the cross-language tests rely on.

/// SplitMix64: tiny, full-period 2^64 generator. Used to seed xoshiro and
/// to derive independent streams from a base seed.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast general-purpose generator with 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) using the top 53 bits.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.next_u64() % n
    }

    /// Fill a vector with standard-normal f32 samples.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Fill a vector with uniform [lo, hi) samples.
    pub fn uniform_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.uniform(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public C reference).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_is_deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut g = Xoshiro256::new(42);
            (0..8).map(|_| g.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut g = Xoshiro256::new(42);
            (0..8).map(|_| g.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut g = Xoshiro256::new(43);
            (0..8).map(|_| g.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_f32_in_range() {
        let mut g = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = g.next_f32();
            assert!((0.0..1.0).contains(&x));
            let y = g.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&y));
        }
    }

    #[test]
    fn normal_moments_are_plausible() {
        let mut g = Xoshiro256::new(11);
        let n = 50_000;
        let xs = g.normal_vec(n);
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut g = Xoshiro256::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[g.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
