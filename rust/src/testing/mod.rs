//! Test support: a property-based testing mini-framework (proptest is
//! unavailable offline) used by unit tests and `rust/tests/properties.rs`,
//! plus the deterministic fault-injection harness behind the
//! `fault-injection` feature (no-op hooks otherwise).

pub mod faults;
pub mod prop;
