//! Test support: a property-based testing mini-framework (proptest is
//! unavailable offline) used by unit tests and `rust/tests/properties.rs`.

pub mod prop;
