//! Property-based testing mini-framework.
//!
//! A deliberately small subset of proptest: seeded generators, a runner
//! that executes N cases, and greedy input shrinking for failures on a few
//! common shapes.  Deterministic per seed; failures print the case number,
//! the (possibly shrunk) input debug form and the assertion message.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath in this
//! // offline environment; the example still compiles)
//! use tina::prop_assert;
//! use tina::testing::prop::{run, Gen};
//! run("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.f32_in(-1e3, 1e3);
//!     let b = g.f32_in(-1e3, 1e3);
//!     prop_assert!(a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use crate::tensor::Tensor;
use crate::tina::{FusionHint, Graph, NodeOp, ValueId};
use crate::util::prng::Xoshiro256;

/// Result type for property bodies: Err(message) fails the case.
pub type PropResult = Result<(), String>;

/// Assertion macro for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}
pub use prop_assert;

/// Per-case value source handed to property bodies.
pub struct Gen {
    rng: Xoshiro256,
    /// Size hint in [0, 1]: early cases draw small values, later cases
    /// larger ones (mimics proptest's progressive sizing).
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Xoshiro256::new(seed),
            size,
        }
    }

    /// Uniform 64-bit value.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// usize in [lo, hi], biased small by the progressive size hint.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo) as f64;
        let scaled = (span * self.size).ceil() as usize;
        lo + (self.rng.next_u64() as usize) % (scaled.max(1) + 1).min(hi - lo + 1)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// Standard-normal f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Vector of standard normals with length in [min_len, max_len].
    pub fn normal_vec(&mut self, min_len: usize, max_len: usize) -> Vec<f32> {
        let n = self.usize_in(min_len, max_len);
        self.rng.normal_vec(n)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.rng.next_u64() as usize) % items.len()]
    }
}

/// Configuration for the runner.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Cases to run per property.
    pub cases: usize,
    /// Base seed (each case derives its own).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 100,
            seed: 0x7177_A7E5_7E57_5EED,
        }
    }
}

/// Run `cases` random cases of `body`; panic with diagnostics on failure.
pub fn run(name: &str, cases: usize, body: impl Fn(&mut Gen) -> PropResult) {
    run_config(
        name,
        Config {
            cases,
            ..Config::default()
        },
        body,
    );
}

/// Runner with explicit config.  On failure, retries the failing seed to
/// confirm determinism and panics with the case's seed so it can be
/// replayed in isolation.
pub fn run_config(name: &str, cfg: Config, body: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // progressive sizing: 10% .. 100% of the range
        let size = 0.1 + 0.9 * (case as f64 / cfg.cases.max(1) as f64);
        let mut g = Gen::new(case_seed, size);
        if let Err(msg) = body(&mut g) {
            // confirm determinism before reporting
            let mut g2 = Gen::new(case_seed, size);
            let second = body(&mut g2);
            panic!(
                "property '{name}' failed at case {case}/{} (seed {case_seed:#x}, \
                 deterministic={}):\n  {msg}",
                cfg.cases,
                second.is_err(),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Random TINA graph generator — the differential fuzzer's input
// ---------------------------------------------------------------------------

/// Build a random **valid** TINA graph (chains and diamonds over the four
/// building-block layers, `Add`/`Sub`, and all four movement ops) plus
/// matching random inputs.  `rust/tests/properties.rs` feeds these to the
/// plan-vs-interpreter differential fuzzer.
///
/// Design constraints that keep the oracle contract *bitwise*:
///
/// * `Add`/`Sub` operands are never `Constant` nodes — adding a
///   per-channel-uniform constant to a layer output would trigger the
///   planner's bias fold, the one documented tolerance-only rewrite;
/// * all dims stay small (≤ 6 per input axis), so hundreds of cases run
///   in milliseconds;
/// * roughly a third of the graphs are STFT-like framing + hinted-window
///   pipelines (with deliberate precondition-breaking variants), so the
///   fusion pass's fold, its skip rules, and the merged-axis materialize
///   elimination are all exercised — equality must hold whether or not a
///   rewrite fires;
/// * a fifth are drawn from the lowering zoo's newer families (complex
///   pairs, unrolled-IIR chains, xcorr pipelines, and Chain-hinted M=1
///   scale chains with their own precondition-breaking variants), so the
///   fuzzer provably reaches every lowering shape `tina::lower` emits.
pub fn random_graph(g: &mut Gen) -> (Graph, Vec<Tensor>) {
    match g.usize_in(0, 9) {
        0..=2 => random_framed_window_graph(g),
        3..=4 => random_lowering_graph(g),
        _ => random_op_graph(g),
    }
}

/// Random factorization of `n` into exactly `rank` factors (order random).
fn factorize(g: &mut Gen, n: usize, rank: usize) -> Vec<usize> {
    let mut dims = Vec::with_capacity(rank);
    let mut rem = n.max(1);
    for _ in 1..rank {
        let divs: Vec<usize> = (1..=rem).filter(|d| rem % d == 0).collect();
        let d = *g.choose(&divs);
        dims.push(d);
        rem /= d;
    }
    dims.push(rem);
    dims
}

/// Pick a pool value, biased toward recently produced ones (chains form,
/// while older values stay reachable so diamonds appear too).
fn pick(g: &mut Gen, pool: &[(ValueId, Vec<usize>)]) -> (ValueId, Vec<usize>) {
    let back = g.usize_in(0, (pool.len() - 1).min(5));
    let (v, s) = &pool[pool.len() - 1 - back];
    (*v, s.clone())
}

/// Reshape `v` to a random `rank`-dim shape with the same element count,
/// registering any new value in the pool.
fn coerce(
    g: &mut Gen,
    gr: &mut Graph,
    pool: &mut Vec<(ValueId, Vec<usize>)>,
    v: ValueId,
    s: &[usize],
    rank: usize,
) -> (ValueId, Vec<usize>) {
    let n: usize = s.iter().product();
    let shape = factorize(g, n, rank);
    if shape.as_slice() == s {
        return (v, shape);
    }
    let nv = gr.push(NodeOp::Reshape(shape.clone()), &[v]);
    pool.push((nv, shape.clone()));
    (nv, shape)
}

/// Append one random op (layer, elementwise, or movement) to the graph.
fn random_op(g: &mut Gen, gr: &mut Graph, pool: &mut Vec<(ValueId, Vec<usize>)>) {
    let (v, s) = pick(g, pool);
    match g.usize_in(0, 9) {
        0 => {
            // depthwise conv; M == 1 windows are sometimes hinted so the
            // fold's verifier sees arbitrary (usually unfoldable) inputs
            let (x, xs) = coerce(g, gr, pool, v, &s, 3);
            let (t, c, w) = (xs[0], xs[1], xs[2]);
            let m = g.usize_in(1, w);
            let k = gr.constant(Tensor::randn(&[c, m], g.u64()));
            let b = gr.constant(Tensor::randn(&[c], g.u64()));
            let hint = if m == 1 && g.bool() {
                FusionHint::Window
            } else {
                FusionHint::None
            };
            let o = gr.push_with_hint(NodeOp::DepthwiseConv1d, &[x, k, b], hint);
            pool.push((o, vec![t, c, w - m + 1]));
        }
        1 => {
            // standard conv; a quarter of the kernels are one-hot ±1 with
            // zero bias (the fold's framing-conv shape)
            let (x, xs) = coerce(g, gr, pool, v, &s, 3);
            let (t, cin, w) = (xs[0], xs[1], xs[2]);
            let cout = g.usize_in(1, 4);
            let n = g.usize_in(1, w);
            let (kt, bt) = if g.usize_in(0, 3) == 0 {
                let mut kd = vec![0.0f32; cout * cin * n];
                for co in 0..cout {
                    let pos = g.usize_in(0, cin * n - 1);
                    kd[co * cin * n + pos] = if g.bool() { 1.0 } else { -1.0 };
                }
                (
                    Tensor::new(&[cout, cin, n], kd).unwrap(),
                    Tensor::zeros(&[cout]),
                )
            } else {
                (
                    Tensor::randn(&[cout, cin, n], g.u64()),
                    Tensor::randn(&[cout], g.u64()),
                )
            };
            let k = gr.constant(kt);
            let b = gr.constant(bt);
            let o = gr.push(NodeOp::StandardConv1d, &[x, k, b]);
            pool.push((o, vec![t, cout, w - n + 1]));
        }
        2 => {
            let (x, xs) = coerce(g, gr, pool, v, &s, 3);
            let (t, cin, sp) = (xs[0], xs[1], xs[2]);
            let cout = g.usize_in(1, 4);
            let k = gr.constant(Tensor::randn(&[cin, cout], g.u64()));
            let b = gr.constant(Tensor::randn(&[cout], g.u64()));
            let o = gr.push(NodeOp::PointwiseConv, &[x, k, b]);
            pool.push((o, vec![t, cout, sp]));
        }
        3 => {
            let (x, xs) = coerce(g, gr, pool, v, &s, 2);
            let (bsz, cin) = (xs[0], xs[1]);
            let cout = g.usize_in(1, 4);
            let k = gr.constant(Tensor::randn(&[cin, cout], g.u64()));
            let b = gr.constant(Tensor::randn(&[cout], g.u64()));
            let o = gr.push(NodeOp::FullyConnected, &[x, k, b]);
            pool.push((o, vec![bsz, cout]));
        }
        4 | 5 => {
            // Add/Sub over same-shape pool values (never constants; a
            // self-pair makes a diamond)
            let same: Vec<ValueId> = pool
                .iter()
                .filter(|(_, ps)| ps == &s)
                .map(|(pv, _)| *pv)
                .collect();
            let other = *g.choose(&same);
            let op = if g.bool() { NodeOp::Add } else { NodeOp::Sub };
            let o = gr.push(op, &[v, other]);
            pool.push((o, s));
        }
        6 => {
            let (x, xs) = coerce(g, gr, pool, v, &s, 2);
            let o = gr.push(NodeOp::Transpose2, &[x]);
            pool.push((o, vec![xs[1], xs[0]]));
        }
        7 => {
            let (x, xs) = coerce(g, gr, pool, v, &s, 3);
            let p = *g.choose(&[
                [0usize, 1, 2],
                [0, 2, 1],
                [1, 0, 2],
                [1, 2, 0],
                [2, 0, 1],
                [2, 1, 0],
            ]);
            let o = gr.push(NodeOp::Permute3(p), &[x]);
            pool.push((o, vec![xs[p[0]], xs[p[1]], xs[p[2]]]));
        }
        8 => {
            let axis = g.usize_in(0, s.len() - 1);
            let d = s[axis];
            let stride = g.usize_in(1, d);
            let count = g.usize_in(1, (d - 1) / stride + 1);
            let o = gr.push(NodeOp::StridedSlice { axis, stride, count }, &[v]);
            let mut os = s.clone();
            os[axis] = count;
            pool.push((o, os));
        }
        _ => {
            let rank = g.usize_in(1, 3);
            let _ = coerce(g, gr, pool, v, &s, rank);
        }
    }
}

fn random_op_graph(g: &mut Gen) -> (Graph, Vec<Tensor>) {
    let mut gr = Graph::new();
    let mut pool: Vec<(ValueId, Vec<usize>)> = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..g.usize_in(1, 3) {
        let rank = g.usize_in(1, 3);
        let shape: Vec<usize> = (0..rank).map(|_| g.usize_in(1, 6)).collect();
        let v = gr.input(&shape);
        inputs.push(Tensor::randn(&shape, g.u64()));
        pool.push((v, shape));
    }
    for _ in 0..g.usize_in(2, 8) {
        random_op(g, &mut gr, &mut pool);
    }
    // one or two distinct outputs, biased toward the newest values (views
    // and diamonds both end up as terminal outputs this way)
    let mut outs: Vec<ValueId> = Vec::new();
    for _ in 0..g.usize_in(1, 2) {
        let idx = pool.len() - 1 - g.usize_in(0, (pool.len() - 1).min(3));
        if !outs.contains(&pool[idx].0) {
            outs.push(pool[idx].0);
        }
    }
    gr.set_outputs(&outs);
    (gr, inputs)
}

/// STFT-like framing + hinted window pipeline with deliberate variants:
/// 0 = cleanly foldable, 1 = window output shared by an `Add` (fold must
/// skip), 2 = dense (non-one-hot) framing kernel (fold must skip), 3 =
/// framed view is also an output (fold must skip).
fn random_framed_window_graph(g: &mut Gen) -> (Graph, Vec<Tensor>) {
    let b = g.usize_in(1, 3);
    let nfft = *g.choose(&[2usize, 4, 8]);
    let hop = g.usize_in(1, nfft);
    let frames = g.usize_in(1, 4);
    let l = nfft + hop * (frames - 1) + g.usize_in(0, 3);
    let variant = g.usize_in(0, 3);
    let mut gr = Graph::new();
    let x = gr.input(&[b, l]);
    let xi = gr.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
    let kt = if variant == 2 {
        Tensor::randn(&[nfft, 1, nfft], g.u64())
    } else {
        // identity framing taps, rows randomly sign-flipped (±1 stays
        // foldable)
        let mut t = Tensor::eye(nfft).reshape(&[nfft, 1, nfft]).unwrap();
        for tap in t.data_mut().iter_mut() {
            if *tap != 0.0 && g.bool() {
                *tap = -*tap;
            }
        }
        t
    };
    let k = gr.constant(kt);
    let bias0 = gr.constant(Tensor::zeros(&[nfft]));
    let unfolded = gr.push(NodeOp::StandardConv1d, &[xi, k, bias0]);
    let framed = gr.push(
        NodeOp::StridedSlice {
            axis: 2,
            stride: hop,
            count: frames,
        },
        &[unfolded],
    );
    let framed = gr.push(NodeOp::Permute3([0, 2, 1]), &[framed]);
    let rows = gr.push(NodeOp::Reshape(vec![b * frames, nfft, 1]), &[framed]);
    let kwin = gr.constant(Tensor::randn(&[nfft, 1], g.u64()));
    let bias_w = gr.constant(if g.bool() {
        Tensor::randn(&[nfft], g.u64())
    } else {
        Tensor::zeros(&[nfft])
    });
    let xw = gr.push_with_hint(
        NodeOp::DepthwiseConv1d,
        &[rows, kwin, bias_w],
        FusionHint::Window,
    );
    let kd = gr.constant(Tensor::randn(&[nfft, nfft], g.u64()));
    let bias_d = gr.constant(Tensor::zeros(&[nfft]));
    let pw = gr.push(NodeOp::PointwiseConv, &[xw, kd, bias_d]);
    let out = gr.push(NodeOp::Reshape(vec![b * frames, nfft]), &[pw]);
    let mut outs = vec![out];
    match variant {
        1 => outs.push(gr.push(NodeOp::Add, &[xw, xw])),
        3 => outs.push(framed),
        _ => {}
    }
    gr.set_outputs(&outs);
    (gr, vec![Tensor::randn(&[b, l], g.u64())])
}

/// Pipelines from the lowering zoo's newer families — complex pairs,
/// unrolled-IIR chains, xcorr — built through `tina::lower` itself so the
/// fuzzer exercises the exact graphs users compile, plus hand-rolled
/// scale chains for the Chain fold's skip rules.
fn random_lowering_graph(g: &mut Gen) -> (Graph, Vec<Tensor>) {
    use crate::tina::lower;
    let b = g.usize_in(1, 3);
    match g.usize_in(0, 4) {
        0 => {
            let n = g.usize_in(1, 6);
            let gr = lower::complex_mul(b, n);
            let inputs = (0..4).map(|_| Tensor::randn(&[b, n], g.u64())).collect();
            (gr, inputs)
        }
        1 => {
            let n = g.usize_in(1, 6);
            let gr = lower::magnitude_sq(b, n);
            let inputs = (0..2).map(|_| Tensor::randn(&[b, n], g.u64())).collect();
            (gr, inputs)
        }
        2 => {
            let mb = g.usize_in(1, 3);
            let na = g.usize_in(1, 2);
            let depth = g.usize_in(1, 3);
            let l = mb + depth * na + g.usize_in(1, 6);
            let b_taps: Vec<f32> = (0..mb).map(|_| g.normal_f32()).collect();
            let a_taps: Vec<f32> = (0..na).map(|_| 0.3 * g.normal_f32()).collect();
            let gr = lower::iir(b, l, &b_taps, &a_taps, depth).unwrap();
            (gr, vec![Tensor::randn(&[b, l], g.u64())])
        }
        3 => {
            let m = g.usize_in(1, 4);
            let l = m + g.usize_in(0, 6);
            let gr = lower::xcorr(b, l, m).unwrap();
            let inputs = vec![Tensor::randn(&[b, l], g.u64()), Tensor::randn(&[m], g.u64())];
            (gr, inputs)
        }
        _ => random_scale_chain_graph(g, b),
    }
}

/// M = 1 depthwise gain stage plus a Chain-hinted link, with deliberate
/// precondition-breaking variants: 0 = cleanly foldable (±1 taps, zero
/// bias), 1 = non-±1 link taps (fold must skip), 2 = nonzero link bias
/// (skip), 3 = gain-stage output shared as a graph output (skip).
fn random_scale_chain_graph(g: &mut Gen, b: usize) -> (Graph, Vec<Tensor>) {
    let n = g.usize_in(1, 6);
    let variant = g.usize_in(0, 3);
    let mut gr = Graph::new();
    let x = gr.input(&[b, n]);
    let xi = gr.push(NodeOp::Reshape(vec![b, n, 1]), &[x]);
    let kg = gr.constant(Tensor::randn(&[n, 1], g.u64()));
    let pb = gr.constant(Tensor::randn(&[n], g.u64()));
    let scaled = gr.push(NodeOp::DepthwiseConv1d, &[xi, kg, pb]);
    let kl = gr.constant(if variant == 1 {
        Tensor::randn(&[n, 1], g.u64())
    } else {
        let taps: Vec<f32> = (0..n).map(|_| if g.bool() { 1.0 } else { -1.0 }).collect();
        Tensor::new(&[n, 1], taps).unwrap()
    });
    let bl = gr.constant(if variant == 2 {
        Tensor::randn(&[n], g.u64())
    } else {
        Tensor::zeros(&[n])
    });
    let link = gr.push_with_hint(NodeOp::DepthwiseConv1d, &[scaled, kl, bl], FusionHint::Chain);
    let kd = gr.constant(Tensor::randn(&[n, n], g.u64()));
    let bd = gr.constant(Tensor::zeros(&[n]));
    let pw = gr.push(NodeOp::PointwiseConv, &[link, kd, bd]);
    let out = gr.push(NodeOp::Reshape(vec![b, n]), &[pw]);
    let mut outs = vec![out];
    if variant == 3 {
        outs.push(scaled);
    }
    gr.set_outputs(&outs);
    (gr, vec![Tensor::randn(&[b, n], g.u64())])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        run("count", 50, |g| {
            let _ = g.u64();
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property 'must-fail' failed")]
    fn failing_property_panics_with_name() {
        run("must-fail", 20, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x <= 42, "x = {x} exceeded 42");
            Ok(())
        });
    }

    #[test]
    fn usize_in_respects_bounds() {
        run("bounds", 200, |g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(0, 10);
            let x = g.usize_in(lo, hi);
            prop_assert!(x >= lo && x <= hi, "x={x} not in [{lo}, {hi}]");
            Ok(())
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let collect = |seed: u64| -> Vec<u64> {
            let mut g = Gen::new(seed, 0.5);
            (0..10).map(|_| g.u64()).collect()
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }

    #[test]
    fn random_graphs_are_valid_and_runnable() {
        // the generator must only ever emit graphs that validate and run:
        // an invalid graph would make every fuzz failure ambiguous
        run("generator soundness", 60, |g| {
            let (graph, inputs) = random_graph(g);
            graph.validate().map_err(|e| format!("invalid graph: {e}"))?;
            prop_assert!(
                inputs.len() == graph.inputs.len(),
                "generator input arity mismatch"
            );
            crate::tina::Interpreter::new(graph)
                .unwrap()
                .run(&inputs)
                .map_err(|e| format!("interpreter rejected generated graph: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn random_graphs_cover_framed_window_pipelines() {
        // a fixed slice of seeds must include some hinted-window graphs,
        // or the fuzzer would silently stop exercising the fold
        let mut hinted = 0;
        for seed in 0..40u64 {
            let mut g = Gen::new(seed, 0.8);
            let (graph, _) = random_graph(&mut g);
            if graph.nodes.iter().any(|n| n.hint == FusionHint::Window) {
                hinted += 1;
            }
        }
        assert!(hinted > 0, "no hinted window graphs in 40 seeds");
    }

    #[test]
    fn random_graphs_cover_new_lowering_families() {
        // fixed seed slices must reach the newer families too, or the
        // fuzzer would silently stop exercising the Chain fold and the
        // complex/IIR lowering shapes
        let (mut chain_hinted, mut complex_pairs, mut iir_chains) = (0, 0, 0);
        for seed in 0..80u64 {
            let mut g = Gen::new(seed, 0.8);
            let (graph, inputs) = random_graph(&mut g);
            if graph.nodes.iter().any(|n| n.hint == FusionHint::Chain) {
                chain_hinted += 1;
            }
            if inputs.len() == 4 {
                complex_pairs += 1;
            }
            let convs = graph
                .nodes
                .iter()
                .filter(|n| matches!(n.op, NodeOp::StandardConv1d))
                .count();
            if convs >= 2 && graph.nodes.iter().any(|n| matches!(n.op, NodeOp::Add)) {
                iir_chains += 1;
            }
        }
        assert!(chain_hinted > 0, "no Chain-hinted graphs in 80 seeds");
        assert!(complex_pairs > 0, "no complex-mul graphs in 80 seeds");
        assert!(iir_chains > 0, "no unrolled-IIR-like graphs in 80 seeds");
    }

    #[test]
    fn choose_covers_all() {
        let items = [1, 2, 3];
        let mut seen = [false; 3];
        let mut g = Gen::new(9, 1.0);
        for _ in 0..100 {
            seen[*g.choose(&items) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
