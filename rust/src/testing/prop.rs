//! Property-based testing mini-framework.
//!
//! A deliberately small subset of proptest: seeded generators, a runner
//! that executes N cases, and greedy input shrinking for failures on a few
//! common shapes.  Deterministic per seed; failures print the case number,
//! the (possibly shrunk) input debug form and the assertion message.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath in this
//! // offline environment; the example still compiles)
//! use tina::prop_assert;
//! use tina::testing::prop::{run, Gen};
//! run("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.f32_in(-1e3, 1e3);
//!     let b = g.f32_in(-1e3, 1e3);
//!     prop_assert!(a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use crate::util::prng::Xoshiro256;

/// Result type for property bodies: Err(message) fails the case.
pub type PropResult = Result<(), String>;

/// Assertion macro for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}
pub use prop_assert;

/// Per-case value source handed to property bodies.
pub struct Gen {
    rng: Xoshiro256,
    /// Size hint in [0, 1]: early cases draw small values, later cases
    /// larger ones (mimics proptest's progressive sizing).
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Xoshiro256::new(seed),
            size,
        }
    }

    /// Uniform 64-bit value.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// usize in [lo, hi], biased small by the progressive size hint.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo) as f64;
        let scaled = (span * self.size).ceil() as usize;
        lo + (self.rng.next_u64() as usize) % (scaled.max(1) + 1).min(hi - lo + 1)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// Standard-normal f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Vector of standard normals with length in [min_len, max_len].
    pub fn normal_vec(&mut self, min_len: usize, max_len: usize) -> Vec<f32> {
        let n = self.usize_in(min_len, max_len);
        self.rng.normal_vec(n)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.rng.next_u64() as usize) % items.len()]
    }
}

/// Configuration for the runner.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Cases to run per property.
    pub cases: usize,
    /// Base seed (each case derives its own).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 100,
            seed: 0x7177_A7E5_7E57_5EED,
        }
    }
}

/// Run `cases` random cases of `body`; panic with diagnostics on failure.
pub fn run(name: &str, cases: usize, body: impl Fn(&mut Gen) -> PropResult) {
    run_config(
        name,
        Config {
            cases,
            ..Config::default()
        },
        body,
    );
}

/// Runner with explicit config.  On failure, retries the failing seed to
/// confirm determinism and panics with the case's seed so it can be
/// replayed in isolation.
pub fn run_config(name: &str, cfg: Config, body: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // progressive sizing: 10% .. 100% of the range
        let size = 0.1 + 0.9 * (case as f64 / cfg.cases.max(1) as f64);
        let mut g = Gen::new(case_seed, size);
        if let Err(msg) = body(&mut g) {
            // confirm determinism before reporting
            let mut g2 = Gen::new(case_seed, size);
            let second = body(&mut g2);
            panic!(
                "property '{name}' failed at case {case}/{} (seed {case_seed:#x}, \
                 deterministic={}):\n  {msg}",
                cfg.cases,
                second.is_err(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        run("count", 50, |g| {
            let _ = g.u64();
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property 'must-fail' failed")]
    fn failing_property_panics_with_name() {
        run("must-fail", 20, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x <= 42, "x = {x} exceeded 42");
            Ok(())
        });
    }

    #[test]
    fn usize_in_respects_bounds() {
        run("bounds", 200, |g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(0, 10);
            let x = g.usize_in(lo, hi);
            prop_assert!(x >= lo && x <= hi, "x={x} not in [{lo}, {hi}]");
            Ok(())
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let collect = |seed: u64| -> Vec<u64> {
            let mut g = Gen::new(seed, 0.5);
            (0..10).map(|_| g.u64()).collect()
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }

    #[test]
    fn choose_covers_all() {
        let items = [1, 2, 3];
        let mut seen = [false; 3];
        let mut g = Gen::new(9, 1.0);
        for _ in 0..100 {
            seen[*g.choose(&items) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
