//! Deterministic fault-injection harness for the chaos test suite.
//!
//! The serving stack calls [`fire`] / [`refused`] at a handful of *named
//! sites* (batch execution, direct execution, exec-pool submit, admission
//! gate).  Without the `fault-injection` cargo feature both hooks compile
//! to inlined no-ops, so production builds carry zero overhead.  With the
//! feature enabled, tests [`arm`] a site with a [`Fault`] and a firing
//! [`Mode`]; decisions are a pure function of `(seed, site, hit index)`,
//! so a given seed replays the exact same fault schedule on every run.
//!
//! The registry is **process-global**: chaos tests serialize on a shared
//! mutex and call [`reset`] before and after each scenario so armed rules
//! never leak across tests (`rust/tests/chaos.rs`).
//!
//! Named sites currently wired into the stack:
//!
//! | site                  | hook      | effect when armed                      |
//! |-----------------------|-----------|----------------------------------------|
//! | `plan.execute`        | [`fire`]  | inside `ExecPlan` step execution       |
//! | `exec.batch.fallback` | [`fire`]  | bucketed fallback batch, pre-execution |
//! | `exec.batch.artifact` | [`fire`]  | artifact batch, pre-execution          |
//! | `exec.direct`         | [`fire`]  | direct (unbatched) path, pre-execution |
//! | `exec_pool.submit`    | [`refused`] | exec pool rejects the batch job      |
//! | `gate.acquire`        | [`refused`] | admission gate reports saturation    |

#[cfg(feature = "fault-injection")]
pub use imp::{arm, hits, reset, Fault, Mode};

/// Evaluate the named fault site.
///
/// Returns `Err` for an armed engine-error fault, panics for an armed
/// panic fault, sleeps (then returns `Ok`) for an armed slow fault, and
/// returns `Ok(())` otherwise.  A no-op without the `fault-injection`
/// feature.
#[cfg(not(feature = "fault-injection"))]
#[inline(always)]
pub fn fire(_site: &str) -> anyhow::Result<()> {
    Ok(())
}

/// Whether the named refusal site (spawn refusal, gate saturation) is
/// armed and fires on this hit.  Always `false` without the
/// `fault-injection` feature.
#[cfg(not(feature = "fault-injection"))]
#[inline(always)]
pub fn refused(_site: &str) -> bool {
    false
}

#[cfg(feature = "fault-injection")]
pub use imp::{fire, refused};

#[cfg(feature = "fault-injection")]
mod imp {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    use std::time::Duration;

    /// What an armed site does when its [`Mode`] says it fires.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Fault {
        /// `panic!` at the site (exercises `catch_unwind` containment).
        Panic,
        /// Sleep for the given duration, then proceed normally.
        Slow(Duration),
        /// Return an `anyhow` error from the site.
        Error,
        /// Report refusal at a [`refused`]-style site (spawn refusal /
        /// gate saturation).  Ignored by [`fire`] sites.
        Refuse,
    }

    /// How often an armed site fires.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Mode {
        /// Fire on the first `n` hits, then behave normally.
        Times(u64),
        /// Fire on every hit until [`reset`].
        Always,
        /// Fire on roughly `percent`% of hits, decided by a deterministic
        /// hash of `(seed, site, hit index)` — the same seed replays the
        /// same schedule.
        Ratio {
            /// Seed mixed into the per-hit decision hash.
            seed: u64,
            /// Firing probability in percent, clamped to 0..=100.
            percent: u8,
        },
    }

    struct Rule {
        fault: Fault,
        mode: Mode,
        fired: u64,
    }

    #[derive(Default)]
    struct Registry {
        rules: HashMap<String, Rule>,
        hits: HashMap<String, u64>,
    }

    fn registry() -> &'static Mutex<Registry> {
        static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(Registry::default()))
    }

    /// FNV-1a over the site name, splitmix-finalized with the seed and
    /// hit index: a cheap, dependency-free deterministic decision hash.
    fn decision_hash(seed: u64, site: &str, hit: u64) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in site.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        let mut z = h ^ seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ hit;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Arm `site` with a fault and firing mode, replacing any prior rule
    /// (and resetting its fired count, not its hit count).
    pub fn arm(site: &str, fault: Fault, mode: Mode) {
        let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.rules.insert(site.to_string(), Rule { fault, mode, fired: 0 });
    }

    /// Clear every armed rule and hit counter.  Chaos tests call this
    /// before and after each scenario; the registry is process-global.
    pub fn reset() {
        let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.rules.clear();
        reg.hits.clear();
    }

    /// Number of times `site` has been evaluated since the last [`reset`]
    /// (fired or not) — lets tests assert a site was actually reached.
    pub fn hits(site: &str) -> u64 {
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.hits.get(site).copied().unwrap_or(0)
    }

    /// Decide (under the registry lock) what `site` does on this hit.
    fn decide(site: &str, refusal: bool) -> Option<Fault> {
        let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        let hit = {
            let h = reg.hits.entry(site.to_string()).or_insert(0);
            let now = *h;
            *h += 1;
            now
        };
        let rule = reg.rules.get_mut(site)?;
        if refusal != matches!(rule.fault, Fault::Refuse) {
            return None;
        }
        let fires = match rule.mode {
            Mode::Times(n) => rule.fired < n,
            Mode::Always => true,
            Mode::Ratio { seed, percent } => {
                decision_hash(seed, site, hit) % 100 < percent.min(100) as u64
            }
        };
        if fires {
            rule.fired += 1;
            Some(rule.fault)
        } else {
            None
        }
    }

    /// Evaluate the named fault site (see module docs for the table).
    pub fn fire(site: &str) -> anyhow::Result<()> {
        match decide(site, false) {
            Some(Fault::Panic) => panic!("fault-injection: injected panic at {site}"),
            Some(Fault::Slow(d)) => {
                // sleep outside the registry lock (decide() released it)
                std::thread::sleep(d);
                Ok(())
            }
            Some(Fault::Error) => Err(anyhow::anyhow!("fault-injection: injected error at {site}")),
            Some(Fault::Refuse) | None => Ok(()),
        }
    }

    /// Whether the named refusal site fires on this hit.
    pub fn refused(site: &str) -> bool {
        matches!(decide(site, true), Some(Fault::Refuse))
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        // The registry is process-global; these unit tests share it with
        // nothing else in the lib target, but still serialize for safety.
        fn serial() -> std::sync::MutexGuard<'static, ()> {
            static LOCK: Mutex<()> = Mutex::new(());
            LOCK.lock().unwrap_or_else(|e| e.into_inner())
        }

        #[test]
        fn times_mode_fires_exactly_n() {
            let _g = serial();
            reset();
            arm("t.site", Fault::Error, Mode::Times(2));
            assert!(fire("t.site").is_err());
            assert!(fire("t.site").is_err());
            assert!(fire("t.site").is_ok());
            assert_eq!(hits("t.site"), 3);
            reset();
        }

        #[test]
        fn ratio_mode_is_deterministic() {
            let _g = serial();
            reset();
            arm("r.site", Fault::Error, Mode::Ratio { seed: 7, percent: 50 });
            let first: Vec<bool> = (0..64).map(|_| fire("r.site").is_err()).collect();
            reset();
            arm("r.site", Fault::Error, Mode::Ratio { seed: 7, percent: 50 });
            let second: Vec<bool> = (0..64).map(|_| fire("r.site").is_err()).collect();
            assert_eq!(first, second, "same seed must replay the same schedule");
            assert!(first.iter().any(|&f| f), "50% over 64 hits should fire");
            assert!(!first.iter().all(|&f| f), "…but not on every hit");
            reset();
        }

        #[test]
        fn refusal_sites_ignore_fire_and_vice_versa() {
            let _g = serial();
            reset();
            arm("x.site", Fault::Refuse, Mode::Always);
            assert!(fire("x.site").is_ok(), "fire ignores Refuse rules");
            assert!(refused("x.site"));
            arm("x.site", Fault::Error, Mode::Always);
            assert!(!refused("x.site"), "refused ignores fire-style rules");
            assert!(fire("x.site").is_err());
            reset();
        }

        #[test]
        fn unarmed_sites_are_quiet() {
            let _g = serial();
            reset();
            assert!(fire("nobody.armed.this").is_ok());
            assert!(!refused("nobody.armed.this"));
            reset();
        }
    }
}
