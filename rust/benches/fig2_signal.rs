//! Fig. 2 reproduction: runtime of the signal-processing functions vs
//! input size.
//!
//! Panels: (a) DFT, (b) IDFT, (c) FIR filter, (d) unfolding.
//!
//! Expected shape (paper §5.1): the direct-jnp path (jaxref, which lowers
//! to the native FFT op) leads on DFT/IDFT with TINA second; on the
//! loop-heavy FIR and unfolding panels the compiled TINA graphs win by
//! orders of magnitude over the naive loop baseline.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{filter_sizes, FigureBench, Panel};
use tina::baselines::{naive, optimized};
use tina::benchkit::black_box;
use tina::coordinator::{ImplPref, OpKind, OpRequest, Router, RouterConfig, Target};
use tina::tensor::{ComplexTensor, Tensor};

fn main() {
    let fb = FigureBench::new();
    let router = fb
        .engine
        .as_ref()
        .map(|e| Router::new(e.registry().clone(), RouterConfig::default()));
    dft_panel(&fb, router.as_ref(), false);
    dft_panel(&fb, router.as_ref(), true);
    fir_panel(&fb, router.as_ref());
    unfold_panel(&fb, router.as_ref());
}

fn interp_of(
    router: Option<&Router>,
    op: OpKind,
    inputs: &[Tensor],
) -> Option<std::sync::Arc<tina::tina::Interpreter>> {
    let router = router?;
    let req = OpRequest::new(op, inputs.to_vec()).with_impl(ImplPref::Interp);
    match router.route(&req).ok()? {
        Target::Interp { key } => router.interpreter(&key, &req).ok(),
        _ => None,
    }
}

fn dft_panel(fb: &FigureBench, router: Option<&Router>, inverse: bool) {
    let (label, csv, opname) = if inverse {
        ("Fig 2b: IDFT runtime vs N (batch of 4)", "fig2b_idft.csv", "idft")
    } else {
        ("Fig 2a: DFT runtime vs N (batch of 4)", "fig2a_dft.csv", "dft")
    };
    let mut panel = Panel::new(label);
    for n in filter_sizes(&[64, 128, 256, 512]) {
        let b = 4;
        let re = Tensor::randn(&[b, n], 11);
        let im = Tensor::randn(&[b, n], 12);
        let size = format!("N={n}");
        let z = ComplexTensor::new(re.clone(), im.clone()).unwrap();
        let zr = ComplexTensor::from_real(re.clone());

        let nv = fb.bench_fn(|| {
            black_box(if inverse {
                naive::idft(&z).unwrap()
            } else {
                naive::dft(&zr).unwrap()
            });
        });
        panel.add("naive", &size, nv, nv);
        let ov = fb.bench_fn(|| {
            black_box(if inverse {
                optimized::idft(&z).unwrap()
            } else {
                optimized::dft(&zr).unwrap()
            });
        });
        panel.add("optimized(FFT)", &size, ov, nv);

        let inputs: Vec<Tensor> = if inverse {
            vec![re.clone(), im.clone()]
        } else {
            vec![re.clone()]
        };
        let op = if inverse { OpKind::Idft } else { OpKind::Dft };
        if let Some(it) = interp_of(router, op, &inputs) {
            let iv = fb.bench_fn(|| {
                black_box(it.run(&inputs).unwrap());
            });
            panel.add("interp", &size, iv, nv);
        }
        for impl_ in ["tina", "jaxref"] {
            let name = format!("{opname}_{impl_}_f32_B{b}_N{n}");
            if let Some(s) = fb.bench_artifact(&name, &inputs) {
                panel.add(impl_, &size, s, nv);
            }
        }
    }
    panel.render_and_save(csv);
}

fn fir_panel(fb: &FigureBench, router: Option<&Router>) {
    let mut panel = Panel::new("Fig 2c: FIR (64 taps) runtime vs L");
    let taps = tina::dsp::fir_lowpass(64, 0.25).unwrap();
    for l in filter_sizes(&[1024, 4096, 16384, 65536]) {
        let x = Tensor::randn(&[1, l], 13);
        let size = format!("L={l}");

        let nv = fb.bench_fn(|| {
            black_box(naive::fir(&x, &taps).unwrap());
        });
        panel.add("naive", &size, nv, nv);
        let ov = fb.bench_fn(|| {
            black_box(optimized::fir(&x, &taps).unwrap());
        });
        panel.add("optimized", &size, ov, nv);

        if let Some(it) = interp_of(router, OpKind::Fir, std::slice::from_ref(&x)) {
            let iv = fb.bench_fn(|| {
                black_box(it.run(std::slice::from_ref(&x)).unwrap());
            });
            panel.add("interp", &size, iv, nv);
        }
        for impl_ in ["tina", "jaxref"] {
            let name = format!("fir_{impl_}_f32_B1_L{l}");
            if let Some(s) = fb.bench_artifact(&name, std::slice::from_ref(&x)) {
                panel.add(impl_, &size, s, nv);
            }
        }
    }
    panel.render_and_save("fig2c_fir.csv");
}

fn unfold_panel(fb: &FigureBench, router: Option<&Router>) {
    let mut panel = Panel::new("Fig 2d: unfolding (J=32) runtime vs L");
    for l in filter_sizes(&[1024, 4096, 16384, 65536]) {
        let x = Tensor::randn(&[1, l], 14);
        let size = format!("L={l}");

        let nv = fb.bench_fn(|| {
            black_box(naive::unfold(&x, 32).unwrap());
        });
        panel.add("naive", &size, nv, nv);
        let ov = fb.bench_fn(|| {
            black_box(optimized::unfold(&x, 32).unwrap());
        });
        panel.add("optimized", &size, ov, nv);

        if let Some(it) = interp_of(router, OpKind::Unfold, std::slice::from_ref(&x)) {
            let iv = fb.bench_fn(|| {
                black_box(it.run(std::slice::from_ref(&x)).unwrap());
            });
            panel.add("interp", &size, iv, nv);
        }
        for impl_ in ["tina", "jaxref"] {
            let name = format!("unfold_{impl_}_f32_B1_L{l}");
            if let Some(s) = fb.bench_artifact(&name, std::slice::from_ref(&x)) {
                panel.add(impl_, &size, s, nv);
            }
        }
    }
    panel.render_and_save("fig2d_unfold.csv");
}
