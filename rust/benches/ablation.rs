//! Ablations over the design choices DESIGN.md calls out:
//!
//!  1. dynamic batching on/off — throughput of B=1 FIR requests;
//!  2. fused PFB artifact vs two-stage pipeline (pfb_fir -> dft) — the L2
//!     fusion benefit;
//!  3. executable cache — first-execution (compile) vs steady-state cost;
//!  4. PJRT artifact vs pure-rust interpreter per op — what the compiled
//!     graph buys over naive layer-by-layer evaluation;
//!  5. paper measurement protocol (device-resident inputs) vs full host
//!     round-trip;
//!  6. naive interpreter vs planned executor on the fallback path — what
//!     plan caching + zero-copy strided views + weight pre-packing +
//!     register tiling + arena reuse + threading buy when no artifact
//!     matches;
//!  7. solo vs batched fallback serving — what the shape-bucketed batcher
//!     (coalesced planned execution at bucket batch sizes) buys over
//!     per-request execution, across arrival burst sizes;
//!  8. plan-level fusion on/off — what the window-into-framing-conv fold
//!     plus merged-axis materialize elimination buy on STFT (and that the
//!     pass is a no-op on the window-less PFB), at B ∈ {1, 8};
//!  9. planned executor vs the virtual-accelerator backend — what the
//!     load-time specialization into a linear program buys over the
//!     step-walking planned executor on PFB and STFT, at B ∈ {1, 8}
//!     (plus, under `--features vaccel`, the full engine-dispatch cost).
//!
//! Ablations 6-9 need no artifacts, so they run first; the rest print
//! in numeric order (or skip with a note).
//!
//! Besides the human-readable tables, every ablation that ran contributes
//! to `BENCH_exec.json` at the repo root — median ns/iter per case and a
//! geomean per ablation — so CI and future PRs can track the perf
//! trajectory mechanically.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{fmt, FigureBench};
use std::sync::Arc;
use tina::benchkit::{black_box, Table};
use tina::coordinator::{
    Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest, Pipeline,
};
use tina::runtime::Engine;
use tina::tensor::Tensor;
use tina::util::json::Json;

/// Geometric mean of strictly-positive samples.
fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| x.max(1e-9).ln()).sum::<f64>() / xs.len() as f64).exp()
}

fn main() {
    let mut report: Vec<(&str, Json)> = Vec::new();
    report.push(("ablation6_interp_vs_planned", interp_vs_planned()));
    report.push(("ablation7_batched_fallback", batched_fallback_ablation()));
    report.push(("ablation8_plan_fusion", plan_fusion_ablation()));
    report.push(("ablation9_vaccel_backend", vaccel_backend_ablation()));
    report.push(("ablation10_new_lowerings", new_lowerings_ablation()));
    if let Some(j) = batching_ablation() {
        report.push(("ablation1_batching", j));
    }
    if let Some(j) = fusion_ablation() {
        report.push(("ablation2_fusion", j));
    }
    if let Some(j) = compile_cache_ablation() {
        report.push(("ablation3_compile_cache", j));
    }
    if let Some(j) = interp_vs_pjrt() {
        report.push(("ablation4_interp_vs_pjrt", j));
    }
    if let Some(j) = measurement_protocol_ablation() {
        report.push(("ablation5_protocol", j));
    }
    let out = Json::obj(report);
    // benches run with the package manifest dir as cwd context; the repo
    // root is one level up from rust/
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_exec.json");
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// 6. fallback execution engines: naive interpreter vs planned executor
/// (strided views + packed kernels + arena + fusion + threaded rows) on
/// the graphs the router lowers when no artifact matches.  Pure rust —
/// needs no artifacts.
fn interp_vs_planned() -> Json {
    use tina::dsp::PfbConfig;
    use tina::tina::{lower, ExecPlan, Interpreter};

    let cfg = tina::benchkit::BenchConfig::from_env();
    let mut t = Table::new(
        "ablation 6: naive interpreter vs planned fallback executor",
        &["graph", "interp median", "planned median", "planned speedup"],
    );
    let pfb_cfg = PfbConfig::new(32, 8);
    let cases: Vec<(String, tina::tina::Graph, Vec<Tensor>)> = vec![
        (
            "pfb B=8 L=16384".into(),
            lower::pfb(8, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[8, 16384], 1)],
        ),
        (
            "pfb_fir B=8 L=16384".into(),
            lower::pfb_fir(8, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[8, 16384], 2)],
        ),
        (
            "stft B=8 L=4096".into(),
            lower::stft(8, 4096, 256, 128).unwrap(),
            vec![Tensor::randn(&[8, 4096], 3)],
        ),
        (
            "fir B=8 L=16384".into(),
            lower::fir(8, 16384, &tina::dsp::fir_lowpass(64, 0.25).unwrap()).unwrap(),
            vec![Tensor::randn(&[8, 16384], 4)],
        ),
        (
            "dft B=8 N=256".into(),
            lower::dft(8, 256),
            vec![Tensor::randn(&[8, 256], 5)],
        ),
    ];
    let mut speedups: Vec<f64> = Vec::new();
    let mut planned_ns: Vec<f64> = Vec::new();
    let mut case_json: Vec<(String, Json)> = Vec::new();
    for (label, graph, inputs) in cases {
        let interp = Interpreter::new(graph.clone()).unwrap();
        let plan = ExecPlan::compile(&graph).unwrap();
        let iv = tina::benchkit::run(&cfg, || {
            black_box(interp.run(&inputs).unwrap());
        })
        .summary();
        // steady-state serving: plan compiled once, arena recycled
        let mut arena = tina::tina::Arena::new();
        let pv = tina::benchkit::run(&cfg, || {
            black_box(plan.run_in(&mut arena, &inputs).unwrap());
        })
        .summary();
        let speedup = pv.speedup_vs(&iv);
        speedups.push(speedup.max(1e-9));
        planned_ns.push(pv.median_ns);
        case_json.push((
            label.clone(),
            Json::obj(vec![
                ("interp_ns", Json::num(iv.median_ns)),
                ("planned_ns", Json::num(pv.median_ns)),
                ("speedup", Json::num(speedup)),
            ]),
        ));
        t.row(vec![
            label,
            fmt(iv.median_ns),
            fmt(pv.median_ns),
            format!("{speedup:.2}x"),
        ]);
    }
    let g = geomean(&speedups);
    t.row(vec![
        "geomean".into(),
        String::new(),
        String::new(),
        format!("{g:.2}x"),
    ]);
    println!("{}", t.render());
    Json::obj(vec![
        ("geomean_speedup", Json::num(g)),
        ("geomean_planned_ns", Json::num(geomean(&planned_ns))),
        (
            "cases",
            Json::Obj(case_json.into_iter().collect()),
        ),
    ])
}

/// 8. plan-level fusion on/off: the same graph compiled with and without
/// the fusion pass (window fold + merged-axis materialize elimination),
/// run steady-state on recycled arenas.  STFT carries a foldable window
/// at two spectral regimes — nfft=32 is movement-bound (the eliminated
/// copy and folded pass are a visible fraction) while nfft=256 is
/// DFT-compute-bound — and PFB is the no-window control where the pass
/// must change nothing.  Pure rust — needs no artifacts.
///
/// The gated headline is the geomean fused-vs-unfused speedup over the
/// STFT cases (a same-machine ratio); PFB ratios are reported as
/// informational fields only.
fn plan_fusion_ablation() -> Json {
    use tina::dsp::PfbConfig;
    use tina::tina::{lower, CompileOptions, ExecPlan};

    let cfg = tina::benchkit::BenchConfig::from_env();
    let mut t = Table::new(
        "ablation 8: plan-level fusion (window fold + copy elimination), B in {1, 8}",
        &["graph", "unfused median", "fused median", "fused speedup"],
    );
    let pfb_cfg = PfbConfig::new(32, 8);
    let cases: Vec<(String, bool, tina::tina::Graph, Vec<Tensor>)> = vec![
        (
            "stft B=1 L=4096 nfft=32".into(),
            true,
            lower::stft(1, 4096, 32, 16).unwrap(),
            vec![Tensor::randn(&[1, 4096], 81)],
        ),
        (
            "stft B=8 L=4096 nfft=32".into(),
            true,
            lower::stft(8, 4096, 32, 16).unwrap(),
            vec![Tensor::randn(&[8, 4096], 82)],
        ),
        (
            "stft B=1 L=4096 nfft=256".into(),
            true,
            lower::stft(1, 4096, 256, 128).unwrap(),
            vec![Tensor::randn(&[1, 4096], 83)],
        ),
        (
            "stft B=8 L=4096 nfft=256".into(),
            true,
            lower::stft(8, 4096, 256, 128).unwrap(),
            vec![Tensor::randn(&[8, 4096], 84)],
        ),
        (
            "pfb B=1 L=16384".into(),
            false,
            lower::pfb(1, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[1, 16384], 85)],
        ),
        (
            "pfb B=8 L=16384".into(),
            false,
            lower::pfb(8, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[8, 16384], 86)],
        ),
    ];
    let mut top: Vec<(&str, Json)> = Vec::new();
    let mut case_json: Vec<(String, Json)> = Vec::new();
    let mut stft_speedups: Vec<f64> = Vec::new();
    for (label, is_stft, graph, inputs) in cases {
        let fused = ExecPlan::compile(&graph).unwrap();
        let unfused =
            ExecPlan::compile_with(
                &graph,
                CompileOptions {
                    fusion: false,
                    verify: false,
                },
            )
            .unwrap();
        if is_stft {
            assert!(fused.fused_steps() > 0, "{label}: window must fold");
        } else {
            assert_eq!(fused.fused_steps(), 0, "{label}: pfb has no window");
        }
        let mut arena_f = tina::tina::Arena::new();
        let fv = tina::benchkit::run(&cfg, || {
            black_box(fused.run_in(&mut arena_f, &inputs).unwrap());
        })
        .summary();
        let mut arena_u = tina::tina::Arena::new();
        let uv = tina::benchkit::run(&cfg, || {
            black_box(unfused.run_in(&mut arena_u, &inputs).unwrap());
        })
        .summary();
        let speedup = uv.median_ns / fv.median_ns.max(1e-9);
        if is_stft {
            stft_speedups.push(speedup.max(1e-9));
        }
        case_json.push((
            label.clone(),
            Json::obj(vec![
                ("unfused_ns", Json::num(uv.median_ns)),
                ("fused_ns", Json::num(fv.median_ns)),
                (
                    if is_stft {
                        "fused_vs_unfused"
                    } else {
                        "pfb_control_ratio"
                    },
                    Json::num(speedup),
                ),
                ("fused_steps", Json::num(fused.fused_steps() as f64)),
                (
                    "eliminated_copies",
                    Json::num(fused.fusion_eliminated_copies() as f64),
                ),
            ]),
        ));
        t.row(vec![
            label,
            fmt(uv.median_ns),
            fmt(fv.median_ns),
            format!("{speedup:.2}x"),
        ]);
    }
    let g = geomean(&stft_speedups);
    t.row(vec![
        "geomean (stft)".into(),
        String::new(),
        String::new(),
        format!("{g:.2}x"),
    ]);
    println!("{}", t.render());
    top.push(("geomean_stft_fusion_speedup", Json::num(g)));
    top.push(("cases", Json::Obj(case_json.into_iter().collect())));
    Json::obj(top)
}

/// Full vaccel engine-dispatch cost for one case — bounded queue hop,
/// worker execution, one-shot reply — as an informational JSON field.
#[cfg(feature = "vaccel")]
fn vaccel_engine_dispatch_ns(
    cfg: &tina::benchkit::BenchConfig,
    plan: &tina::tina::ExecPlan,
    inputs: &[Tensor],
) -> Option<f64> {
    let engine = tina::runtime::VaccelEngine::with_defaults();
    engine.load("bench", plan).ok()?;
    let v = tina::benchkit::run(cfg, || {
        black_box(engine.try_execute("bench", inputs).unwrap());
    })
    .summary();
    Some(v.median_ns)
}

/// Without the feature the queue/worker layer does not exist; the linear
/// program itself (the part that executes the math) is measured above.
#[cfg(not(feature = "vaccel"))]
fn vaccel_engine_dispatch_ns(
    _cfg: &tina::benchkit::BenchConfig,
    _plan: &tina::tina::ExecPlan,
    _inputs: &[Tensor],
) -> Option<f64> {
    None
}

/// 9. planned executor vs the virtual-accelerator backend on the same
/// compiled plans: `ExecPlan` walked step-by-step with a recycled arena
/// (the fallback serving path) vs the load-time-specialized
/// `LinearProgram` the vaccel backend executes.  The specialization is
/// ungated, so the comparison runs on every build; `--features vaccel`
/// additionally reports the full engine-dispatch median per case.
/// Outputs are asserted bitwise-equal outside the timed loops — the
/// backends differ in dispatch, never in math.
fn vaccel_backend_ablation() -> Json {
    use tina::dsp::PfbConfig;
    use tina::tina::{lower, ExecPlan, LinearProgram};

    let cfg = tina::benchkit::BenchConfig::from_env();
    let mut t = Table::new(
        "ablation 9: planned executor vs vaccel linear program, B in {1, 8}",
        &["graph", "planned median", "vaccel median", "vaccel speedup"],
    );
    let pfb_cfg = PfbConfig::new(32, 8);
    let cases: Vec<(String, tina::tina::Graph, Vec<Tensor>)> = vec![
        (
            "pfb B=1 L=16384".into(),
            lower::pfb(1, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[1, 16384], 91)],
        ),
        (
            "pfb B=8 L=16384".into(),
            lower::pfb(8, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[8, 16384], 92)],
        ),
        (
            "stft B=1 L=4096".into(),
            lower::stft(1, 4096, 256, 128).unwrap(),
            vec![Tensor::randn(&[1, 4096], 93)],
        ),
        (
            "stft B=8 L=4096".into(),
            lower::stft(8, 4096, 256, 128).unwrap(),
            vec![Tensor::randn(&[8, 4096], 94)],
        ),
    ];
    let mut speedups: Vec<f64> = Vec::new();
    let mut case_json: Vec<(String, Json)> = Vec::new();
    for (label, graph, inputs) in cases {
        let plan = ExecPlan::compile(&graph).unwrap();
        let program = LinearProgram::load(&plan).unwrap();
        // oracle contract spot-check before timing anything
        let mut arena = tina::tina::Arena::new();
        let want = plan.run_in(&mut arena, &inputs).unwrap();
        let got = program.run(&inputs).unwrap();
        assert_eq!(want, got, "{label}: vaccel program diverged bitwise");
        let pv = tina::benchkit::run(&cfg, || {
            black_box(plan.run_in(&mut arena, &inputs).unwrap());
        })
        .summary();
        let lv = tina::benchkit::run(&cfg, || {
            black_box(program.run(&inputs).unwrap());
        })
        .summary();
        let speedup = pv.median_ns / lv.median_ns.max(1e-9);
        speedups.push(speedup.max(1e-9));
        let mut fields = vec![
            ("planned_ns", Json::num(pv.median_ns)),
            ("vaccel_ns", Json::num(lv.median_ns)),
            ("vaccel_vs_planned", Json::num(speedup)),
        ];
        if let Some(engine_ns) = vaccel_engine_dispatch_ns(&cfg, &plan, &inputs) {
            fields.push(("engine_dispatch_ns", Json::num(engine_ns)));
        }
        case_json.push((label.clone(), Json::obj(fields)));
        t.row(vec![
            label,
            fmt(pv.median_ns),
            fmt(lv.median_ns),
            format!("{speedup:.2}x"),
        ]);
    }
    let g = geomean(&speedups);
    t.row(vec![
        "geomean".into(),
        String::new(),
        String::new(),
        format!("{g:.2}x"),
    ]);
    println!("{}", t.render());
    Json::obj(vec![
        ("geomean_vaccel_vs_planned_speedup", Json::num(g)),
        ("cases", Json::Obj(case_json.into_iter().collect())),
    ])
}

/// 10. the PR-9 lowering zoo: (a) the ONE-graph spectrometer vs the
/// staged pipeline it replaces (PFB plan, then a separate
/// square-and-integrate plan with a host hop between them) — what
/// compiling the whole instrument as a single fused plan buys; and
/// (b) the unrolled-IIR depth sweep — planned-executor speedup over the
/// naive interpreter at each unroll depth, showing the cost model of the
/// paper's iterative-function strategy.  Pure rust — needs no artifacts.
///
/// Gated headlines (same-machine ratios): geomean staged-vs-fused
/// spectrometer speedup over B ∈ {1, 8}, and geomean planned-vs-interp
/// IIR speedup over the depth sweep.  Outputs are asserted bitwise-equal
/// and the fused spectrometer copy-free outside the timed loops.
fn new_lowerings_ablation() -> Json {
    use tina::dsp::PfbConfig;
    use tina::tina::{lower, Arena, ExecPlan, Graph, Interpreter, NodeOp};

    let cfg = tina::benchkit::BenchConfig::from_env();
    let mut t = Table::new(
        "ablation 10: lowering zoo — staged vs one-plan spectrometer; IIR depth sweep",
        &["case", "baseline median", "subject median", "speedup"],
    );
    let mut case_json: Vec<(String, Json)> = Vec::new();

    // (a) spectrometer: staged two-plan pipeline vs the single fused plan
    let pfb_cfg = PfbConfig::new(32, 8);
    let l = 16384usize;
    let (p, mt) = (pfb_cfg.branches, pfb_cfg.taps_per_branch);
    let ns = l / p - mt + 1;
    // stage 2 of the staged pipeline: take lower::pfb's (B, Ns, P)
    // spectra, permute back to (B, P, Ns), square + integrate exactly
    // like the fused graph's tail
    let stage2 = |b: usize| -> Graph {
        let q = b * p * ns;
        let mut g2 = Graph::new();
        let re_in = g2.input(&[b, ns, p]);
        let im_in = g2.input(&[b, ns, p]);
        let rep = g2.push(NodeOp::Permute3([0, 2, 1]), &[re_in]);
        let imp = g2.push(NodeOp::Permute3([0, 2, 1]), &[im_in]);
        let sq = |gr: &mut Graph, v| {
            let a = gr.push(NodeOp::Reshape(vec![1, q, 1]), &[v]);
            let k = gr.push(NodeOp::Reshape(vec![q, 1]), &[v]);
            let bias = gr.constant(Tensor::zeros(&[q]));
            gr.push(NodeOp::DepthwiseConv1d, &[a, k, bias])
        };
        let rr = sq(&mut g2, rep);
        let ii = sq(&mut g2, imp);
        let pow = g2.push(NodeOp::Add, &[rr, ii]);
        let rows = g2.push(NodeOp::Reshape(vec![b * p, ns]), &[pow]);
        let ksum = g2.constant(Tensor::ones(&[ns, 1]));
        let b1 = g2.constant(Tensor::zeros(&[1]));
        let o = g2.push(NodeOp::FullyConnected, &[rows, ksum, b1]);
        let o = g2.push(NodeOp::Reshape(vec![b, p]), &[o]);
        g2.set_outputs(&[o]);
        g2
    };
    let mut spec_speedups: Vec<f64> = Vec::new();
    for b in [1usize, 8] {
        let fused = ExecPlan::compile(&lower::spectrometer(b, l, pfb_cfg).unwrap()).unwrap();
        assert_eq!(
            fused.materialize_count(),
            0,
            "spectrometer B={b}: one-plan compile must be copy-free"
        );
        let stage1 = ExecPlan::compile(&lower::pfb(b, l, pfb_cfg).unwrap()).unwrap();
        let integ = ExecPlan::compile(&stage2(b)).unwrap();
        let inputs = vec![Tensor::randn(&[b, l], 100 + b as u64)];
        // bitwise contract before timing: staging only moves data
        let mut arena = Arena::new();
        let want = fused.run_in(&mut arena, &inputs).unwrap();
        let spectra = stage1.run_in(&mut arena, &inputs).unwrap();
        let got = integ.run_in(&mut arena, &spectra).unwrap();
        assert_eq!(want, got, "spectrometer B={b}: staged diverged bitwise");
        let mut arena_f = Arena::new();
        let fv = tina::benchkit::run(&cfg, || {
            black_box(fused.run_in(&mut arena_f, &inputs).unwrap());
        })
        .summary();
        let mut arena_s = Arena::new();
        let sv = tina::benchkit::run(&cfg, || {
            let spectra = stage1.run_in(&mut arena_s, &inputs).unwrap();
            black_box(integ.run_in(&mut arena_s, &spectra).unwrap());
        })
        .summary();
        let speedup = sv.median_ns / fv.median_ns.max(1e-9);
        spec_speedups.push(speedup.max(1e-9));
        let label = format!("spectrometer B={b} L={l}");
        case_json.push((
            label.clone(),
            Json::obj(vec![
                ("staged_ns", Json::num(sv.median_ns)),
                ("fused_ns", Json::num(fv.median_ns)),
                ("staged_vs_fused", Json::num(speedup)),
            ]),
        ));
        t.row(vec![
            label,
            fmt(sv.median_ns),
            fmt(fv.median_ns),
            format!("{speedup:.2}x"),
        ]);
    }

    // (b) IIR depth sweep: planned executor vs naive interpreter per
    // unroll depth (deeper unrolls mean more conv levels for the same
    // output prefix — the accuracy/latency dial of paper §3)
    let (b_taps, a_taps) = ([0.25f32, 0.5, 0.25], [0.3f32, 0.15]);
    let mut iir_speedups: Vec<f64> = Vec::new();
    for depth in [1usize, 2, 4, 8] {
        let graph = lower::iir(8, 16384, &b_taps, &a_taps, depth).unwrap();
        let interp = Interpreter::new(graph.clone()).unwrap();
        let plan = ExecPlan::compile(&graph).unwrap();
        let inputs = vec![Tensor::randn(&[8, 16384], 110 + depth as u64)];
        let iv = tina::benchkit::run(&cfg, || {
            black_box(interp.run(&inputs).unwrap());
        })
        .summary();
        let mut arena = Arena::new();
        let pv = tina::benchkit::run(&cfg, || {
            black_box(plan.run_in(&mut arena, &inputs).unwrap());
        })
        .summary();
        let speedup = pv.speedup_vs(&iv);
        iir_speedups.push(speedup.max(1e-9));
        let label = format!("iir B=8 L=16384 depth={depth}");
        case_json.push((
            label.clone(),
            Json::obj(vec![
                ("interp_ns", Json::num(iv.median_ns)),
                ("planned_ns", Json::num(pv.median_ns)),
                ("speedup", Json::num(speedup)),
            ]),
        ));
        t.row(vec![
            label,
            fmt(iv.median_ns),
            fmt(pv.median_ns),
            format!("{speedup:.2}x"),
        ]);
    }

    let gs = geomean(&spec_speedups);
    let gi = geomean(&iir_speedups);
    t.row(vec![
        "geomean (spectrometer / iir)".into(),
        String::new(),
        String::new(),
        format!("{gs:.2}x / {gi:.2}x"),
    ]);
    println!("{}", t.render());
    Json::obj(vec![
        ("geomean_staged_vs_fused_spectrometer_speedup", Json::num(gs)),
        ("geomean_iir_planned_speedup", Json::num(gi)),
        ("cases", Json::Obj(case_json.into_iter().collect())),
    ])
}

/// 7. solo vs batched fallback serving: B=1 FIR requests with no matching
/// artifact, arriving in bursts, served either per request (batching off)
/// or coalesced by the shape-bucketed batcher into one planned execution
/// per bucket (batching on).  Pure rust — needs no artifacts.
///
/// Arrival pattern: `total` requests submitted open-loop in bursts of k
/// (all bursts issued before any reply is awaited), so the batcher sees a
/// sustained queue the way a loaded server would.  A final "mixed" case
/// interleaves burst sizes 1/2/4/8.
fn batched_fallback_ablation() -> Json {
    use std::path::PathBuf;
    use tina::runtime::Registry;

    let l = 4096usize;
    let total = 64usize;
    let make = |batching: bool| {
        let registry = Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .expect("empty manifest");
        Arc::new(
            Coordinator::new(
                registry,
                CoordinatorConfig {
                    batching,
                    ..Default::default()
                },
            )
            .expect("coordinator"),
        )
    };
    // pass count honors TINA_BENCH_PROFILE like the other ablations
    // (quick=5 iters -> 5 passes; default/paper clamp at 9): the headline
    // speedups are CI-gated, so one noisy pass must not decide them
    let cfg = tina::benchkit::BenchConfig::from_env();
    let passes = cfg.iters.clamp(3, 9);
    // one pass: submit `total` requests in the burst pattern, wait for
    // every reply, return req/s
    let drive = |coord: &Arc<Coordinator>, bursts: &[usize]| -> f64 {
        let mut slots = Vec::with_capacity(total);
        let t0 = std::time::Instant::now();
        let mut issued = 0usize;
        'outer: loop {
            for &k in bursts {
                for _ in 0..k {
                    if issued == total {
                        break 'outer;
                    }
                    let x = Tensor::randn(&[1, l], issued as u64);
                    slots.push(coord.submit(OpRequest::new(OpKind::Fir, vec![x])));
                    issued += 1;
                }
            }
        }
        for s in slots {
            s.wait().expect("fallback request");
        }
        total as f64 / t0.elapsed().as_secs_f64()
    };
    // median req/s over `passes` driven passes (after one warmup pass)
    let measure = |coord: &Arc<Coordinator>, bursts: &[usize]| -> f64 {
        let _ = drive(coord, bursts);
        let mut rates: Vec<f64> = (0..passes).map(|_| drive(coord, bursts)).collect();
        rates.sort_by(f64::total_cmp);
        rates[rates.len() / 2]
    };

    let mut t = Table::new(
        "ablation 7: solo vs shape-bucketed batched fallback (64 x B=1 FIR L=4096)",
        &["arrival bursts", "solo req/s", "batched req/s", "batched/solo"],
    );
    let patterns: Vec<(String, Vec<usize>)> = vec![
        ("burst1".into(), vec![1]),
        ("burst2".into(), vec![2]),
        ("burst4".into(), vec![4]),
        ("burst8".into(), vec![8]),
        ("mixed".into(), vec![1, 8, 4, 2]),
    ];
    let mut top: Vec<(&str, Json)> = Vec::new();
    let mut cases: Vec<(String, Json)> = Vec::new();
    let mut ratios: Vec<f64> = Vec::new();
    let mut ratio_b4 = 0.0f64;
    let mut ratio_b8 = 0.0f64;
    for (label, bursts) in &patterns {
        let solo_coord = make(false);
        let batched_coord = make(true);
        // warm both plan caches (bucket plans for every power-of-two size
        // plus the solo B=1 plan) so compiles stay out of the timed pass
        for b in [1usize, 2, 4, 8] {
            let _ = batched_coord
                .router()
                .planned_for_shapes(OpKind::Fir, &[vec![b, l]]);
        }
        let solo = measure(&solo_coord, bursts);
        let batched = measure(&batched_coord, bursts);
        let ratio = batched / solo.max(1e-9);
        ratios.push(ratio.max(1e-9));
        if label.as_str() == "burst4" {
            ratio_b4 = ratio;
        }
        if label.as_str() == "burst8" {
            ratio_b8 = ratio;
        }
        let m = batched_coord.metrics();
        cases.push((
            label.clone(),
            Json::obj(vec![
                ("solo_req_s", Json::num(solo)),
                ("batched_req_s", Json::num(batched)),
                ("batched_vs_solo", Json::num(ratio)),
                ("batch_fill_ratio", Json::num(m.batch_fill_ratio())),
            ]),
        ));
        t.row(vec![
            label.clone(),
            format!("{solo:.0}"),
            format!("{batched:.0}"),
            format!("{ratio:.2}x"),
        ]);
        solo_coord.shutdown();
        batched_coord.shutdown();
    }
    let g = geomean(&ratios);
    t.row(vec![
        "geomean".into(),
        String::new(),
        String::new(),
        format!("{g:.2}x"),
    ]);
    println!("{}", t.render());
    top.push(("geomean_batched_vs_solo_speedup", Json::num(g)));
    top.push(("burst4_batched_vs_solo_speedup", Json::num(ratio_b4)));
    top.push(("burst8_batched_vs_solo_speedup", Json::num(ratio_b8)));
    top.push(("cases", Json::Obj(cases.into_iter().collect())));
    Json::obj(top)
}

/// 5. paper protocol (device-resident inputs) vs full host round-trip —
/// quantifies what the literal upload/fetch adds per request size.
fn measurement_protocol_ablation() -> Option<Json> {
    let fb = FigureBench::new();
    fb.engine.as_ref()?;
    let mut t = Table::new(
        "ablation 5: device-resident (paper protocol) vs host round-trip",
        &["artifact", "device-resident", "host round-trip", "upload+fetch overhead"],
    );
    let mut cases: Vec<(String, Json)> = Vec::new();
    for (name, shape) in [
        ("fir_tina_f32_B1_L1024", vec![1usize, 1024]),
        ("fir_tina_f32_B1_L65536", vec![1, 65536]),
        ("pfb_tina_f32_B1_L16384", vec![1, 16384]),
        ("matmul_tina_f32_N256", vec![256, 256]),
    ] {
        let inputs: Vec<Tensor> = if name.starts_with("matmul") {
            vec![Tensor::randn(&shape, 1), Tensor::randn(&shape, 2)]
        } else {
            vec![Tensor::randn(&shape, 1)]
        };
        let (Some(dev), Some(host)) = (
            fb.bench_artifact(name, &inputs),
            fb.bench_artifact_host(name, &inputs),
        ) else {
            continue;
        };
        cases.push((
            name.to_string(),
            Json::obj(vec![
                ("device_ns", Json::num(dev.median_ns)),
                ("host_ns", Json::num(host.median_ns)),
            ]),
        ));
        t.row(vec![
            name.into(),
            fmt(dev.median_ns),
            fmt(host.median_ns),
            format!("{:.0}%", 100.0 * (host.median_ns - dev.median_ns) / dev.median_ns.max(1.0)),
        ]);
    }
    println!("{}", t.render());
    if cases.is_empty() {
        return None;
    }
    Some(Json::obj(vec![(
        "cases",
        Json::Obj(cases.into_iter().collect()),
    )]))
}

/// 1. batching on/off throughput.
fn batching_ablation() -> Option<Json> {
    let mut t = Table::new(
        "ablation 1: dynamic batching (200 x B=1 FIR L=4096 requests)",
        &["batching", "total", "req/s", "batches", "padded rows"],
    );
    let mut rates: Vec<(&str, Json)> = Vec::new();
    for batching in [true, false] {
        let Ok(coord) = Coordinator::from_dir(
            "artifacts",
            CoordinatorConfig {
                batching,
                ..Default::default()
            },
        ) else {
            eprintln!("no artifacts; skipping batching ablation");
            return None;
        };
        let coord = Arc::new(coord);
        let _ = coord.warmup(Some("fir"));
        let n = 200;
        let t0 = std::time::Instant::now();
        let slots: Vec<_> = (0..n)
            .map(|i| {
                let x = Tensor::randn(&[1, 4096], i as u64);
                coord.submit(OpRequest::new(OpKind::Fir, vec![x]).with_impl(ImplPref::Tina))
            })
            .collect();
        for s in slots {
            s.wait().expect("request");
        }
        let dt = t0.elapsed();
        let m = coord.metrics();
        let rate = n as f64 / dt.as_secs_f64();
        rates.push((
            if batching { "batching_on_req_s" } else { "batching_off_req_s" },
            Json::num(rate),
        ));
        t.row(vec![
            if batching { "on" } else { "off" }.into(),
            format!("{dt:?}"),
            format!("{rate:.0}"),
            m.batches_executed
                .load(std::sync::atomic::Ordering::Relaxed)
                .to_string(),
            m.padded_rows
                .load(std::sync::atomic::Ordering::Relaxed)
                .to_string(),
        ]);
        coord.shutdown();
    }
    println!("{}", t.render());
    Some(Json::obj(rates))
}

/// 2. fused pfb artifact vs two-stage pipeline.
fn fusion_ablation() -> Option<Json> {
    let Ok(coord) = Coordinator::from_dir("artifacts", CoordinatorConfig::default()) else {
        return None;
    };
    let cfg = tina::benchkit::BenchConfig::from_env();
    let x = Tensor::randn(&[1, 16384], 31);
    let mut t = Table::new(
        "ablation 2: fused PFB graph vs two-stage chain (L=16384)",
        &["variant", "median", "note"],
    );

    let fused_req =
        OpRequest::new(OpKind::Pfb, vec![x.clone()]).with_impl(ImplPref::Tina);
    coord.execute(fused_req.clone()).expect("warm fused");
    let fused = tina::benchkit::run(&cfg, || {
        black_box(coord.execute(fused_req.clone()).unwrap());
    })
    .summary();
    t.row(vec![
        "fused artifact".into(),
        fmt(fused.median_ns),
        "single lowered graph (FIR bank + DFT)".into(),
    ]);

    let chain = Pipeline::pfb_two_stage();
    chain.run(&coord, vec![x.clone()]).expect("warm chain");
    let chained = tina::benchkit::run(&cfg, || {
        black_box(chain.run(&coord, vec![x.clone()]).unwrap());
    })
    .summary();
    t.row(vec![
        "two-stage chain".into(),
        fmt(chained.median_ns),
        "pfb_fir artifact + dft stage, host round-trip".into(),
    ]);
    let benefit = chained.median_ns / fused.median_ns.max(1.0);
    t.row(vec![
        "fusion benefit".into(),
        format!("{benefit:.2}x"),
        "chained / fused".into(),
    ]);
    println!("{}", t.render());
    coord.shutdown();
    Some(Json::obj(vec![
        ("fused_ns", Json::num(fused.median_ns)),
        ("chained_ns", Json::num(chained.median_ns)),
        ("fusion_benefit", Json::num(benefit)),
    ]))
}

/// 3. compile-vs-cached execution cost.
fn compile_cache_ablation() -> Option<Json> {
    let Ok(engine) = Engine::from_dir("artifacts") else {
        return None;
    };
    let mut t = Table::new(
        "ablation 3: executable cache (pfb_tina_f32_B1_L16384)",
        &["phase", "time"],
    );
    let name = "pfb_tina_f32_B1_L16384";
    engine.registry().get(name)?;
    let x = Tensor::randn(&[1, 16384], 41);
    let t0 = std::time::Instant::now();
    engine.execute(name, std::slice::from_ref(&x)).unwrap();
    let first = t0.elapsed();
    t.row(vec!["first (compile + run)".into(), format!("{first:?}")]);
    let t1 = std::time::Instant::now();
    engine.execute(name, std::slice::from_ref(&x)).unwrap();
    let second = t1.elapsed();
    t.row(vec!["second (cached)".into(), format!("{second:?}")]);
    let stats = engine.stats();
    t.row(vec![
        "engine stats".into(),
        format!(
            "compiles={} executes={} compile={} execute={}",
            stats.compiles,
            stats.executions,
            fmt(stats.compile_ns as f64),
            fmt(stats.execute_ns as f64)
        ),
    ]);
    println!("{}", t.render());
    Some(Json::obj(vec![
        ("first_ns", Json::num(first.as_nanos() as f64)),
        ("cached_ns", Json::num(second.as_nanos() as f64)),
    ]))
}

/// 4. interpreter vs PJRT per op.
fn interp_vs_pjrt() -> Option<Json> {
    let fb = FigureBench::new();
    let engine = fb.engine.as_ref()?;
    let router = tina::coordinator::Router::new(engine.registry().clone(), Default::default());
    let mut t = Table::new(
        "ablation 4: pure-rust interpreter vs compiled PJRT artifact",
        &["op", "interp median", "pjrt median", "pjrt speedup"],
    );
    let cases: Vec<(OpKind, Vec<Tensor>, String)> = vec![
        (
            OpKind::Fir,
            vec![Tensor::randn(&[1, 16384], 1)],
            "fir_tina_f32_B1_L16384".into(),
        ),
        (
            OpKind::Unfold,
            vec![Tensor::randn(&[1, 16384], 2)],
            "unfold_tina_f32_B1_L16384".into(),
        ),
        (
            OpKind::Pfb,
            vec![Tensor::randn(&[1, 16384], 3)],
            "pfb_tina_f32_B1_L16384".into(),
        ),
        (
            OpKind::MatMul,
            vec![Tensor::randn(&[256, 256], 4), Tensor::randn(&[256, 256], 5)],
            "matmul_tina_f32_N256".into(),
        ),
    ];
    let mut case_json: Vec<(String, Json)> = Vec::new();
    for (op, inputs, artifact) in cases {
        let req = OpRequest::new(op, inputs.clone()).with_impl(ImplPref::Interp);
        let Ok(tina::coordinator::Target::Interp { key }) = router.route(&req) else {
            continue;
        };
        let Ok(it) = router.interpreter(&key, &req) else {
            continue;
        };
        let iv = fb.bench_fn(|| {
            black_box(it.run(&inputs).unwrap());
        });
        let Some(pv) = fb.bench_artifact(&artifact, &inputs) else {
            continue;
        };
        case_json.push((
            op.as_str().to_string(),
            Json::obj(vec![
                ("interp_ns", Json::num(iv.median_ns)),
                ("pjrt_ns", Json::num(pv.median_ns)),
            ]),
        ));
        t.row(vec![
            op.as_str().into(),
            fmt(iv.median_ns),
            fmt(pv.median_ns),
            format!("{:.1}x", pv.speedup_vs(&iv)),
        ]);
    }
    println!("{}", t.render());
    if case_json.is_empty() {
        return None;
    }
    Some(Json::obj(vec![(
        "cases",
        Json::Obj(case_json.into_iter().collect()),
    )]))
}
