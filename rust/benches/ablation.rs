//! Ablations over the design choices DESIGN.md calls out:
//!
//!  1. dynamic batching on/off — throughput of B=1 FIR requests;
//!  2. fused PFB artifact vs two-stage pipeline (pfb_fir -> dft) — the L2
//!     fusion benefit;
//!  3. executable cache — first-execution (compile) vs steady-state cost;
//!  4. PJRT artifact vs pure-rust interpreter per op — what the compiled
//!     graph buys over naive layer-by-layer evaluation;
//!  5. paper measurement protocol (device-resident inputs) vs full host
//!     round-trip;
//!  6. naive interpreter vs planned executor on the fallback path — what
//!     plan caching + arena reuse + fusion + threading buy when no
//!     artifact matches.
//!
//! Ablation 6 is the only one that needs no artifacts, so it runs first;
//! the rest print in numeric order (or skip with a note).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{fmt, FigureBench};
use std::sync::Arc;
use tina::benchkit::{black_box, Table};
use tina::coordinator::{
    Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest, Pipeline,
};
use tina::runtime::Engine;
use tina::tensor::Tensor;

fn main() {
    interp_vs_planned();
    batching_ablation();
    fusion_ablation();
    compile_cache_ablation();
    interp_vs_pjrt();
    measurement_protocol_ablation();
}

/// 6. fallback execution engines: naive interpreter vs planned executor
/// (arena + fusion + threaded rows) on the graphs the router lowers when
/// no artifact matches.  Pure rust — needs no artifacts.
fn interp_vs_planned() {
    use tina::dsp::PfbConfig;
    use tina::tina::{lower, ExecPlan, Interpreter};

    let cfg = tina::benchkit::BenchConfig::from_env();
    let mut t = Table::new(
        "ablation 6: naive interpreter vs planned fallback executor",
        &["graph", "interp median", "planned median", "planned speedup"],
    );
    let pfb_cfg = PfbConfig::new(32, 8);
    let cases: Vec<(String, tina::tina::Graph, Vec<Tensor>)> = vec![
        (
            "pfb B=8 L=16384".into(),
            lower::pfb(8, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[8, 16384], 1)],
        ),
        (
            "pfb_fir B=8 L=16384".into(),
            lower::pfb_fir(8, 16384, pfb_cfg).unwrap(),
            vec![Tensor::randn(&[8, 16384], 2)],
        ),
        (
            "stft B=8 L=4096".into(),
            lower::stft(8, 4096, 256, 128).unwrap(),
            vec![Tensor::randn(&[8, 4096], 3)],
        ),
        (
            "fir B=8 L=16384".into(),
            lower::fir(8, 16384, &tina::dsp::fir_lowpass(64, 0.25).unwrap()).unwrap(),
            vec![Tensor::randn(&[8, 16384], 4)],
        ),
        (
            "dft B=8 N=256".into(),
            lower::dft(8, 256),
            vec![Tensor::randn(&[8, 256], 5)],
        ),
    ];
    let mut speedups: Vec<f64> = Vec::new();
    for (label, graph, inputs) in cases {
        let interp = Interpreter::new(graph.clone()).unwrap();
        let plan = ExecPlan::compile(&graph).unwrap();
        let iv = tina::benchkit::run(&cfg, || {
            black_box(interp.run(&inputs).unwrap());
        })
        .summary();
        // steady-state serving: plan compiled once, arena recycled
        let mut arena = tina::tina::Arena::new();
        let pv = tina::benchkit::run(&cfg, || {
            black_box(plan.run_in(&mut arena, &inputs).unwrap());
        })
        .summary();
        let speedup = pv.speedup_vs(&iv);
        speedups.push(speedup.max(1e-9));
        t.row(vec![
            label,
            fmt(iv.median_ns),
            fmt(pv.median_ns),
            format!("{speedup:.2}x"),
        ]);
    }
    let geomean = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    t.row(vec![
        "geomean".into(),
        String::new(),
        String::new(),
        format!("{geomean:.2}x"),
    ]);
    println!("{}", t.render());
}

/// 5. paper protocol (device-resident inputs) vs full host round-trip —
/// quantifies what the literal upload/fetch adds per request size.
fn measurement_protocol_ablation() {
    let fb = FigureBench::new();
    if fb.engine.is_none() {
        return;
    }
    let mut t = Table::new(
        "ablation 5: device-resident (paper protocol) vs host round-trip",
        &["artifact", "device-resident", "host round-trip", "upload+fetch overhead"],
    );
    for (name, shape) in [
        ("fir_tina_f32_B1_L1024", vec![1usize, 1024]),
        ("fir_tina_f32_B1_L65536", vec![1, 65536]),
        ("pfb_tina_f32_B1_L16384", vec![1, 16384]),
        ("matmul_tina_f32_N256", vec![256, 256]),
    ] {
        let inputs: Vec<Tensor> = if name.starts_with("matmul") {
            vec![Tensor::randn(&shape, 1), Tensor::randn(&shape, 2)]
        } else {
            vec![Tensor::randn(&shape, 1)]
        };
        let (Some(dev), Some(host)) = (
            fb.bench_artifact(name, &inputs),
            fb.bench_artifact_host(name, &inputs),
        ) else {
            continue;
        };
        t.row(vec![
            name.into(),
            fmt(dev.median_ns),
            fmt(host.median_ns),
            format!("{:.0}%", 100.0 * (host.median_ns - dev.median_ns) / dev.median_ns.max(1.0)),
        ]);
    }
    println!("{}", t.render());
}

/// 1. batching on/off throughput.
fn batching_ablation() {
    let mut t = Table::new(
        "ablation 1: dynamic batching (200 x B=1 FIR L=4096 requests)",
        &["batching", "total", "req/s", "batches", "padded rows"],
    );
    for batching in [true, false] {
        let Ok(coord) = Coordinator::from_dir(
            "artifacts",
            CoordinatorConfig {
                batching,
                ..Default::default()
            },
        ) else {
            eprintln!("no artifacts; skipping batching ablation");
            return;
        };
        let coord = Arc::new(coord);
        let _ = coord.warmup(Some("fir"));
        let n = 200;
        let t0 = std::time::Instant::now();
        let slots: Vec<_> = (0..n)
            .map(|i| {
                let x = Tensor::randn(&[1, 4096], i as u64);
                coord.submit(OpRequest::new(OpKind::Fir, vec![x]).with_impl(ImplPref::Tina))
            })
            .collect();
        for s in slots {
            s.wait().expect("request");
        }
        let dt = t0.elapsed();
        let m = coord.metrics();
        t.row(vec![
            if batching { "on" } else { "off" }.into(),
            format!("{dt:?}"),
            format!("{:.0}", n as f64 / dt.as_secs_f64()),
            m.batches_executed
                .load(std::sync::atomic::Ordering::Relaxed)
                .to_string(),
            m.padded_rows
                .load(std::sync::atomic::Ordering::Relaxed)
                .to_string(),
        ]);
        coord.shutdown();
    }
    println!("{}", t.render());
}

/// 2. fused pfb artifact vs two-stage pipeline.
fn fusion_ablation() {
    let Ok(coord) = Coordinator::from_dir("artifacts", CoordinatorConfig::default()) else {
        return;
    };
    let cfg = tina::benchkit::BenchConfig::from_env();
    let x = Tensor::randn(&[1, 16384], 31);
    let mut t = Table::new(
        "ablation 2: fused PFB graph vs two-stage chain (L=16384)",
        &["variant", "median", "note"],
    );

    let fused_req =
        OpRequest::new(OpKind::Pfb, vec![x.clone()]).with_impl(ImplPref::Tina);
    coord.execute(fused_req.clone()).expect("warm fused");
    let fused = tina::benchkit::run(&cfg, || {
        black_box(coord.execute(fused_req.clone()).unwrap());
    })
    .summary();
    t.row(vec![
        "fused artifact".into(),
        fmt(fused.median_ns),
        "single lowered graph (FIR bank + DFT)".into(),
    ]);

    let chain = Pipeline::pfb_two_stage();
    chain.run(&coord, vec![x.clone()]).expect("warm chain");
    let chained = tina::benchkit::run(&cfg, || {
        black_box(chain.run(&coord, vec![x.clone()]).unwrap());
    })
    .summary();
    t.row(vec![
        "two-stage chain".into(),
        fmt(chained.median_ns),
        "pfb_fir artifact + dft stage, host round-trip".into(),
    ]);
    t.row(vec![
        "fusion benefit".into(),
        format!("{:.2}x", chained.median_ns / fused.median_ns.max(1.0)),
        "chained / fused".into(),
    ]);
    println!("{}", t.render());
    coord.shutdown();
}

/// 3. compile-vs-cached execution cost.
fn compile_cache_ablation() {
    let Ok(engine) = Engine::from_dir("artifacts") else {
        return;
    };
    let mut t = Table::new(
        "ablation 3: executable cache (pfb_tina_f32_B1_L16384)",
        &["phase", "time"],
    );
    let name = "pfb_tina_f32_B1_L16384";
    if engine.registry().get(name).is_none() {
        return;
    }
    let x = Tensor::randn(&[1, 16384], 41);
    let t0 = std::time::Instant::now();
    engine.execute(name, std::slice::from_ref(&x)).unwrap();
    t.row(vec!["first (compile + run)".into(), format!("{:?}", t0.elapsed())]);
    let t1 = std::time::Instant::now();
    engine.execute(name, std::slice::from_ref(&x)).unwrap();
    t.row(vec!["second (cached)".into(), format!("{:?}", t1.elapsed())]);
    let stats = engine.stats();
    t.row(vec![
        "engine stats".into(),
        format!(
            "compiles={} executes={} compile={} execute={}",
            stats.compiles,
            stats.executions,
            fmt(stats.compile_ns as f64),
            fmt(stats.execute_ns as f64)
        ),
    ]);
    println!("{}", t.render());
}

/// 4. interpreter vs PJRT per op.
fn interp_vs_pjrt() {
    let fb = FigureBench::new();
    let Some(engine) = fb.engine.as_ref() else {
        return;
    };
    let router = tina::coordinator::Router::new(engine.registry().clone(), Default::default());
    let mut t = Table::new(
        "ablation 4: pure-rust interpreter vs compiled PJRT artifact",
        &["op", "interp median", "pjrt median", "pjrt speedup"],
    );
    let cases: Vec<(OpKind, Vec<Tensor>, String)> = vec![
        (
            OpKind::Fir,
            vec![Tensor::randn(&[1, 16384], 1)],
            "fir_tina_f32_B1_L16384".into(),
        ),
        (
            OpKind::Unfold,
            vec![Tensor::randn(&[1, 16384], 2)],
            "unfold_tina_f32_B1_L16384".into(),
        ),
        (
            OpKind::Pfb,
            vec![Tensor::randn(&[1, 16384], 3)],
            "pfb_tina_f32_B1_L16384".into(),
        ),
        (
            OpKind::MatMul,
            vec![Tensor::randn(&[256, 256], 4), Tensor::randn(&[256, 256], 5)],
            "matmul_tina_f32_N256".into(),
        ),
    ];
    for (op, inputs, artifact) in cases {
        let req = OpRequest::new(op, inputs.clone()).with_impl(ImplPref::Interp);
        let Ok(tina::coordinator::Target::Interp { key }) = router.route(&req) else {
            continue;
        };
        let Ok(it) = router.interpreter(&key, &req) else {
            continue;
        };
        let iv = fb.bench_fn(|| {
            black_box(it.run(&inputs).unwrap());
        });
        let Some(pv) = fb.bench_artifact(&artifact, &inputs) else {
            continue;
        };
        t.row(vec![
            op.as_str().into(),
            fmt(iv.median_ns),
            fmt(pv.median_ns),
            format!("{:.1}x", pv.speedup_vs(&iv)),
        ]);
    }
    println!("{}", t.render());
}
