//! Fig. 1 reproduction: runtime of the arithmetic functions vs input size.
//!
//! Panels: (a) elementwise multiply, (b) matrix-matrix multiply,
//! (c) elementwise add, (d) summation.  Implementations:
//!   naive      — NumPy-on-CPU analog (the paper's baseline)
//!   optimized  — CuPy analog (per-op optimized native, no fusion)
//!   interp     — pure-rust TINA layer interpreter
//!   tina       — TINA NN-layer artifact on PJRT (the paper's TINA-32)
//!   jaxref     — direct-jnp artifact on PJRT (the paper's JAX)
//!
//! Expected shape (paper §5.1): TINA competitive-to-fastest on the
//! multiply-based panels; optimized/CuPy wins the trivial add panel;
//! everything is close on summation.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{filter_sizes, FigureBench, Panel};
use tina::baselines::{naive, optimized};
use tina::benchkit::black_box;
use tina::coordinator::{OpKind, OpRequest, Router, RouterConfig, Target};
use tina::tensor::Tensor;

fn main() {
    let fb = FigureBench::new();
    let router = fb
        .engine
        .as_ref()
        .map(|e| Router::new(e.registry().clone(), RouterConfig::default()));

    elementwise(&fb, router.as_ref(), "ewmult", "fig1a_ewmult.csv");
    matmul_panel(&fb, router.as_ref());
    elementwise(&fb, router.as_ref(), "ewadd", "fig1c_ewadd.csv");
    summation_panel(&fb, router.as_ref());
}

fn interp_of(router: Option<&Router>, op: OpKind, inputs: &[Tensor]) -> Option<std::sync::Arc<tina::tina::Interpreter>> {
    let router = router?;
    let req = OpRequest::new(op, inputs.to_vec()).with_impl(tina::coordinator::ImplPref::Interp);
    match router.route(&req).ok()? {
        Target::Interp { key } => router.interpreter(&key, &req).ok(),
        _ => None,
    }
}

fn elementwise(fb: &FigureBench, router: Option<&Router>, op_name: &str, csv: &str) {
    let op = OpKind::parse(op_name).unwrap();
    let mut panel = Panel::new(&format!(
        "Fig 1{}: {} runtime vs N (N x N matrices)",
        if op_name == "ewmult" { 'a' } else { 'c' },
        op_name
    ));
    for n in filter_sizes(&[32, 64, 128, 256]) {
        let a = Tensor::randn(&[n, n], 1);
        let b = Tensor::randn(&[n, n], 2);
        let size = format!("{n}x{n}");

        let nv = fb.bench_fn(|| {
            black_box(match op {
                OpKind::EwMult => naive::ewmult(&a, &b).unwrap(),
                _ => naive::ewadd(&a, &b).unwrap(),
            });
        });
        panel.add("naive", &size, nv, nv);

        let ov = fb.bench_fn(|| {
            black_box(match op {
                OpKind::EwMult => optimized::ewmult(&a, &b).unwrap(),
                _ => optimized::ewadd(&a, &b).unwrap(),
            });
        });
        panel.add("optimized", &size, ov, nv);

        if let Some(it) = interp_of(router, op, &[a.clone(), b.clone()]) {
            let iv = fb.bench_fn(|| {
                black_box(it.run(&[a.clone(), b.clone()]).unwrap());
            });
            panel.add("interp", &size, iv, nv);
        }

        for impl_ in ["tina", "jaxref"] {
            let name = format!("{op_name}_{impl_}_f32_N{n}");
            if let Some(s) = fb.bench_artifact(&name, &[a.clone(), b.clone()]) {
                panel.add(impl_, &size, s, nv);
            }
        }
    }
    panel.render_and_save(csv);
}

fn matmul_panel(fb: &FigureBench, router: Option<&Router>) {
    let mut panel = Panel::new("Fig 1b: matmul runtime vs N (N x N matrices)");
    for n in filter_sizes(&[32, 64, 128, 256]) {
        let a = Tensor::randn(&[n, n], 3);
        let b = Tensor::randn(&[n, n], 4);
        let size = format!("{n}x{n}");

        let nv = fb.bench_fn(|| {
            black_box(naive::matmul(&a, &b).unwrap());
        });
        panel.add("naive", &size, nv, nv);
        let ov = fb.bench_fn(|| {
            black_box(optimized::matmul(&a, &b).unwrap());
        });
        panel.add("optimized", &size, ov, nv);

        if let Some(it) = interp_of(router, OpKind::MatMul, &[a.clone(), b.clone()]) {
            let iv = fb.bench_fn(|| {
                black_box(it.run(&[a.clone(), b.clone()]).unwrap());
            });
            panel.add("interp", &size, iv, nv);
        }
        for impl_ in ["tina", "jaxref"] {
            let name = format!("matmul_{impl_}_f32_N{n}");
            if let Some(s) = fb.bench_artifact(&name, &[a.clone(), b.clone()]) {
                panel.add(impl_, &size, s, nv);
            }
        }
    }
    panel.render_and_save("fig1b_matmul.csv");
}

fn summation_panel(fb: &FigureBench, router: Option<&Router>) {
    let mut panel = Panel::new("Fig 1d: summation runtime vs L (vector length)");
    for l in filter_sizes(&[1024, 4096, 16384, 65536]) {
        let x = Tensor::randn(&[l], 5);
        let size = format!("L={l}");

        let nv = fb.bench_fn(|| {
            black_box(naive::summation(&x));
        });
        panel.add("naive", &size, nv, nv);
        let ov = fb.bench_fn(|| {
            black_box(optimized::summation(&x));
        });
        panel.add("optimized", &size, ov, nv);

        if let Some(it) = interp_of(router, OpKind::Summation, &[x.clone()]) {
            let iv = fb.bench_fn(|| {
                black_box(it.run(std::slice::from_ref(&x)).unwrap());
            });
            panel.add("interp", &size, iv, nv);
        }
        for impl_ in ["tina", "jaxref"] {
            let name = format!("summation_{impl_}_f32_L{l}");
            if let Some(s) = fb.bench_artifact(&name, std::slice::from_ref(&x)) {
                panel.add(impl_, &size, s, nv);
            }
        }
    }
    panel.render_and_save("fig1d_summation.csv");
}
