//! Fig. 3 reproduction: PFB speedups over the naive CPU baseline.
//!
//! Left column  — subfiltered signals only (the polyphase FIR bank);
//! Right column — full PFB (FIR bank + Fourier transform).
//!
//! Implementations per the paper: CuPy-analog (optimized), TINA 32-bit,
//! TINA 16-bit (bf16 compute), JAX-direct — all as speedup over naive.
//! The paper's headline: TINA-32 25-80x, TINA-16 20-30x, JAX 6-8x on a
//! T4; the *ordering and growth with L* is the reproduction target here,
//! not the absolute GPU factors (DESIGN.md §3).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{filter_sizes, FigureBench, Panel};
use tina::baselines::{naive, optimized};
use tina::benchkit::black_box;
use tina::dsp::PfbConfig;
use tina::tensor::Tensor;

const P: usize = 32;
const M: usize = 8;

fn main() {
    let fb = FigureBench::new();
    let cfg = PfbConfig::new(P, M);
    column(&fb, cfg, "pfb_fir", "Fig 3 left: PFB FIR bank (subfiltered) speedups", "fig3_left_pfb_fir.csv");
    column(&fb, cfg, "pfb", "Fig 3 right: full PFB (FIR + DFT) speedups", "fig3_right_pfb.csv");
}

fn column(fb: &FigureBench, cfg: PfbConfig, op: &str, title: &str, csv: &str) {
    let mut panel = Panel::new(title);
    for l in filter_sizes(&[4096, 16384, 65536]) {
        let x = Tensor::randn(&[1, l], 21);
        let size = format!("L={l}");

        let nv = fb.bench_fn(|| {
            black_box(if op == "pfb" {
                let _ = naive::pfb(&x, cfg).unwrap();
            } else {
                let _ = naive::pfb_fir(&x, cfg).unwrap();
            });
        });
        panel.add("naive", &size, nv, nv);

        let ov = fb.bench_fn(|| {
            black_box(if op == "pfb" {
                let _ = optimized::pfb(&x, cfg).unwrap();
            } else {
                let _ = optimized::pfb_fir(&x, cfg).unwrap();
            });
        });
        panel.add("optimized (CuPy analog)", &size, ov, nv);

        for (label, artifact) in [
            ("TINA 32-bit", format!("{op}_tina_f32_B1_L{l}")),
            ("TINA 16-bit", format!("{op}_tina_bf16_B1_L{l}")),
            ("JAX direct", format!("{op}_jaxref_f32_B1_L{l}")),
        ] {
            if let Some(s) = fb.bench_artifact(&artifact, std::slice::from_ref(&x)) {
                panel.add(label, &size, s, nv);
            }
        }
    }
    panel.render_and_save(csv);
}
