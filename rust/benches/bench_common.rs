//! Shared helpers for the figure-reproduction benches.
//!
//! Every bench binary prints the same table format the paper's figures
//! plot: one row per (implementation, input size) with median runtime and
//! the speedup over the naive CPU baseline (the paper's NumPy
//! denominator).  CSV copies land in `target/bench_results/` so
//! EXPERIMENTS.md numbers can be regenerated mechanically.

use std::path::PathBuf;
use tina::benchkit::{BenchConfig, Stats, Summary, Table};
use tina::runtime::Engine;

pub struct FigureBench {
    pub engine: Option<Engine>,
    pub cfg: BenchConfig,
}

impl FigureBench {
    /// Load the PJRT engine if artifacts exist (benches degrade gracefully
    /// to baseline-only rows without them).
    pub fn new() -> FigureBench {
        let engine = Engine::from_dir("artifacts")
            .map_err(|e| eprintln!("note: no artifacts ({e}); PJRT rows skipped"))
            .ok();
        FigureBench {
            engine,
            cfg: BenchConfig::from_env(),
        }
    }

    /// Measure one artifact execution under the paper's protocol: the
    /// executable is pre-compiled and the inputs are pre-uploaded to device
    /// buffers ("the measurement starts once the input data has been copied
    /// to the GPU memory", §5); the timed region is compute + result fetch.
    pub fn bench_artifact(
        &self,
        name: &str,
        inputs: &[tina::tensor::Tensor],
    ) -> Option<Summary> {
        let engine = self.engine.as_ref()?;
        engine.registry().get(name)?;
        if let Err(e) = engine.prepare(name) {
            eprintln!("prepare {name}: {e}");
            return None;
        }
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| engine.upload(t).expect("upload"))
            .collect();
        let stats: Stats = tina::benchkit::run(&self.cfg, || {
            tina::benchkit::black_box(
                engine.execute_buffers(name, &buffers).expect("execute"),
            );
        });
        Some(stats.summary())
    }

    /// Measure the full host round-trip (literal upload + execute + fetch):
    /// what a serving request actually pays.  Used by the ablation bench.
    pub fn bench_artifact_host(
        &self,
        name: &str,
        inputs: &[tina::tensor::Tensor],
    ) -> Option<Summary> {
        let engine = self.engine.as_ref()?;
        engine.registry().get(name)?;
        engine.prepare(name).ok()?;
        let stats: Stats = tina::benchkit::run(&self.cfg, || {
            tina::benchkit::black_box(engine.execute(name, inputs).expect("execute"));
        });
        Some(stats.summary())
    }

    pub fn bench_fn(&self, mut f: impl FnMut()) -> Summary {
        tina::benchkit::run(&self.cfg, &mut f).summary()
    }
}

/// One figure panel: rows of (impl, size) -> summary, rendered vs naive.
pub struct Panel {
    pub title: String,
    /// (impl name, size label, summary, naive summary at that size)
    rows: Vec<(String, String, Summary, Summary)>,
}

impl Panel {
    pub fn new(title: &str) -> Panel {
        Panel {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn add(&mut self, impl_name: &str, size: &str, s: Summary, naive: Summary) {
        self.rows.push((impl_name.into(), size.into(), s, naive));
    }

    pub fn render_and_save(&self, csv_name: &str) {
        let mut t = Table::new(
            &self.title,
            &["impl", "size", "median", "mean", "p95", "speedup-vs-naive"],
        );
        for (imp, size, s, naive) in &self.rows {
            t.row(vec![
                imp.clone(),
                size.clone(),
                fmt(s.median_ns),
                fmt(s.mean_ns),
                fmt(s.p95_ns),
                format!("{:.2}x", s.speedup_vs(naive)),
            ]);
        }
        println!("{}", t.render());
        let dir = PathBuf::from("target/bench_results");
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(dir.join(csv_name), t.to_csv());
    }
}

pub fn fmt(ns: f64) -> String {
    tina::util::histogram::fmt_ns(ns.max(0.0) as u64)
}

/// Parse sizes override: TINA_BENCH_SIZES="32,64" limits sweeps (CI knob).
pub fn filter_sizes(default: &[usize]) -> Vec<usize> {
    match std::env::var("TINA_BENCH_SIZES") {
        Ok(s) => s
            .split(',')
            .filter_map(|x| x.trim().parse().ok())
            .filter(|x| default.contains(x))
            .collect(),
        Err(_) => default.to_vec(),
    }
}
