//! Property-based tests over the coordinator and substrate invariants,
//! using the in-repo prop-testing kit (`tina::testing::prop`).
//!
//! No artifacts needed — these exercise pure-rust components.

use std::sync::Arc;
use tina::baselines::{naive, optimized};
use tina::coordinator::batcher::{
    scatter_results, BatchKey, Batcher, BatcherConfig, Completion, Pending,
};
use tina::coordinator::{Metrics, OpKind, OpResponse};
use tina::dsp::{self, PfbConfig};
use tina::prop_assert;
use tina::tensor::{ComplexTensor, Tensor};
use tina::testing::prop::{random_graph, run, Gen};
use tina::tina::{
    lower, Arena, CompileOptions, ExecPlan, Graph, Interpreter, LinearProgram, NodeOp, Planned,
};
use tina::util::json::{self, Json};
use tina::util::threadpool::OneShot;

/// Response slot + completion context pair for driving the batcher
/// directly in properties (no coordinator in the loop).
fn test_completion(metrics: &Arc<Metrics>) -> (OneShot<anyhow::Result<OpResponse>>, Completion) {
    let slot: OneShot<anyhow::Result<OpResponse>> = OneShot::new();
    let c = Completion::new(
        Arc::clone(metrics),
        slot.clone(),
        "fir",
        "prop".into(),
        std::time::Instant::now(),
        None,
        None,
    );
    (slot, c)
}

// ---------------------------------------------------------------------------
// mapping invariants: interpreter == baselines for random shapes
// ---------------------------------------------------------------------------

#[test]
fn prop_ewmult_mapping_equals_direct() {
    run("ewmult mapping == a*b", 60, |g: &mut Gen| {
        let h = g.usize_in(1, 24);
        let w = g.usize_in(1, 24);
        let a = Tensor::randn(&[h, w], g.u64());
        let b = Tensor::randn(&[h, w], g.u64());
        let got = Interpreter::new(lower::ewmult(h, w))
            .unwrap()
            .run(&[a.clone(), b.clone()])
            .map_err(|e| e.to_string())?;
        let want = naive::ewmult(&a, &b).unwrap();
        prop_assert!(got[0].allclose(&want, 1e-5, 1e-6), "h={h} w={w}");
        Ok(())
    });
}

#[test]
fn prop_matmul_mapping_equals_direct() {
    run("matmul mapping == X@Y", 40, |g: &mut Gen| {
        let m = g.usize_in(1, 20);
        let l = g.usize_in(1, 24);
        let n = g.usize_in(1, 20);
        let x = Tensor::randn(&[m, l], g.u64());
        let y = Tensor::randn(&[l, n], g.u64());
        let got = Interpreter::new(lower::matmul(m, l, n))
            .unwrap()
            .run(&[x.clone(), y.clone()])
            .map_err(|e| e.to_string())?;
        let want = naive::matmul(&x, &y).unwrap();
        prop_assert!(got[0].allclose(&want, 1e-4, 1e-4), "m={m} l={l} n={n}");
        Ok(())
    });
}

#[test]
fn prop_fir_linearity() {
    // FIR is linear: fir(a*x + y) == a*fir(x) + fir(y)
    run("FIR linearity", 30, |g: &mut Gen| {
        let l = g.usize_in(80, 600);
        let taps = dsp::fir_lowpass(g.usize_in(2, 32), 0.2).unwrap();
        let x = Tensor::randn(&[1, l], g.u64());
        let y = Tensor::randn(&[1, l], g.u64());
        let a = g.f32_in(-3.0, 3.0);
        let lhs_in =
            Tensor::new(&[1, l], x.data().iter().zip(y.data()).map(|(u, v)| a * u + v).collect())
                .unwrap();
        let lhs = naive::fir(&lhs_in, &taps).unwrap();
        let fx = naive::fir(&x, &taps).unwrap();
        let fy = naive::fir(&y, &taps).unwrap();
        let rhs = Tensor::new(
            &[1, lhs.len()],
            fx.data().iter().zip(fy.data()).map(|(u, v)| a * u + v).collect(),
        )
        .unwrap()
        .reshape(lhs.shape())
        .unwrap();
        prop_assert!(lhs.allclose(&rhs, 1e-3, 1e-3), "l={l} a={a}");
        Ok(())
    });
}

#[test]
fn prop_unfold_reconstructs_input() {
    // every input sample appears at the expected unfold coordinates
    run("unfold coordinates", 40, |g: &mut Gen| {
        let j = g.usize_in(1, 16);
        let l = j + g.usize_in(1, 200);
        let x = Tensor::randn(&[1, l], g.u64());
        let u = naive::unfold(&x, j).unwrap();
        let wout = l - j + 1;
        for _ in 0..20 {
            let i = g.usize_in(0, wout - 1);
            let jj = g.usize_in(0, j - 1);
            prop_assert!(
                u.at(&[0, i, jj]) == x.at(&[0, i + jj]),
                "Y[{i},{jj}] != X[{}]",
                i + jj
            );
        }
        Ok(())
    });
}

#[test]
fn prop_dft_parseval_and_inverse() {
    run("DFT Parseval + inverse", 25, |g: &mut Gen| {
        let n = *g.choose(&[4usize, 8, 16, 32, 64]);
        let x = ComplexTensor::from_real(Tensor::randn(&[1, n], g.u64()));
        let z = dsp::dft_direct(&x).map_err(|e| e.to_string())?;
        let ex: f64 = x.re.data().iter().map(|&v| (v * v) as f64).sum();
        let ez: f64 = z
            .re
            .data()
            .iter()
            .zip(z.im.data())
            .map(|(r, i)| (r * r + i * i) as f64)
            .sum();
        prop_assert!(
            (ez - n as f64 * ex).abs() <= 1e-3 * ez.abs().max(1.0),
            "Parseval n={n}: {ez} vs {}",
            n as f64 * ex
        );
        let (ir, ii) = dsp::idft_matrix(n);
        let back = z
            .matmul(&ComplexTensor::new(ir, ii).unwrap())
            .map_err(|e| e.to_string())?;
        prop_assert!(back.allclose(&x, 1e-3, 1e-3), "inverse n={n}");
        Ok(())
    });
}

#[test]
fn prop_fft_equals_direct_dft() {
    run("radix-2 FFT == direct DFT", 25, |g: &mut Gen| {
        let n = *g.choose(&[2usize, 4, 8, 16, 32, 64, 128]);
        let x = ComplexTensor::new(
            Tensor::randn(&[2, n], g.u64()),
            Tensor::randn(&[2, n], g.u64()),
        )
        .unwrap();
        let got = dsp::fft_radix2(&x).map_err(|e| e.to_string())?;
        let want = dsp::dft_direct(&x).map_err(|e| e.to_string())?;
        prop_assert!(got.allclose(&want, 1e-3, 1e-3), "n={n}");
        Ok(())
    });
}

#[test]
fn prop_optimized_baselines_match_naive() {
    run("optimized == naive", 30, |g: &mut Gen| {
        let b = g.usize_in(1, 3);
        let l = g.usize_in(64, 800);
        let x = Tensor::randn(&[b, l], g.u64());
        let taps = dsp::fir_lowpass(g.usize_in(2, 48).min(l), 0.3).unwrap();
        let f1 = naive::fir(&x, &taps).unwrap();
        let f2 = optimized::fir(&x, &taps).unwrap();
        prop_assert!(f1.allclose(&f2, 1e-4, 1e-5), "fir b={b} l={l}");
        let w = g.usize_in(1, l.min(32));
        let u1 = naive::unfold(&x, w).unwrap();
        let u2 = optimized::unfold(&x, w).unwrap();
        prop_assert!(u1 == u2, "unfold b={b} l={l} w={w}");
        Ok(())
    });
}

#[test]
fn prop_pfb_implementations_agree() {
    run("pfb: naive == optimized == interpreter", 15, |g: &mut Gen| {
        let p = *g.choose(&[4usize, 8, 16]);
        let m = g.usize_in(2, 6);
        let nspec = m + g.usize_in(4, 40);
        let l = p * nspec;
        let cfg = PfbConfig::new(p, m);
        let x = Tensor::randn(&[1, l], g.u64());
        let a = naive::pfb_fir(&x, cfg).unwrap();
        let b = optimized::pfb_fir(&x, cfg).unwrap();
        prop_assert!(a.allclose(&b, 1e-4, 1e-5), "optimized p={p} m={m}");
        let it = Interpreter::new(lower::pfb_fir(1, l, cfg).unwrap()).unwrap();
        let c = it.run(&[x.clone()]).map_err(|e| e.to_string())?;
        prop_assert!(a.allclose(&c[0], 1e-4, 1e-5), "interp p={p} m={m}");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// planned executor invariants: the exec plan must match the interpreter
// oracle on every lowering, and its arena schedule must be sound
// ---------------------------------------------------------------------------

/// Build a random graph + matching random inputs for one of the lowerings.
fn random_lowering(g: &mut Gen) -> (Graph, Vec<Tensor>) {
    let which = *g.choose(&[
        0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17,
    ]);
    match which {
        0 => {
            let (h, w) = (g.usize_in(1, 16), g.usize_in(1, 16));
            (
                lower::ewmult(h, w),
                vec![Tensor::randn(&[h, w], g.u64()), Tensor::randn(&[h, w], g.u64())],
            )
        }
        1 => {
            let (h, w) = (g.usize_in(1, 16), g.usize_in(1, 16));
            (
                lower::ewadd(h, w),
                vec![Tensor::randn(&[h, w], g.u64()), Tensor::randn(&[h, w], g.u64())],
            )
        }
        2 => {
            let (m, l, n) = (g.usize_in(1, 12), g.usize_in(1, 16), g.usize_in(1, 12));
            (
                lower::matmul(m, l, n),
                vec![Tensor::randn(&[m, l], g.u64()), Tensor::randn(&[l, n], g.u64())],
            )
        }
        3 => {
            let l = g.usize_in(1, 2000);
            (lower::summation(l), vec![Tensor::randn(&[l], g.u64())])
        }
        4 => {
            let (b, n) = (g.usize_in(1, 4), g.usize_in(2, 24));
            (lower::dft(b, n), vec![Tensor::randn(&[b, n], g.u64())])
        }
        5 => {
            let (b, n) = (g.usize_in(1, 4), g.usize_in(2, 24));
            (
                lower::idft(b, n),
                vec![Tensor::randn(&[b, n], g.u64()), Tensor::randn(&[b, n], g.u64())],
            )
        }
        6 => {
            let taps = dsp::fir_lowpass(g.usize_in(2, 24), 0.2).unwrap();
            let l = taps.len() + g.usize_in(1, 300);
            let b = g.usize_in(1, 3);
            (
                lower::fir(b, l, &taps).unwrap(),
                vec![Tensor::randn(&[b, l], g.u64())],
            )
        }
        7 => {
            let j = g.usize_in(1, 12);
            let l = j + g.usize_in(1, 120);
            let b = g.usize_in(1, 3);
            (
                lower::unfold(b, l, j).unwrap(),
                vec![Tensor::randn(&[b, l], g.u64())],
            )
        }
        8 | 9 => {
            let p = *g.choose(&[4usize, 8]);
            let m = g.usize_in(2, 5);
            let l = p * (m + g.usize_in(2, 24));
            let b = g.usize_in(1, 3);
            let cfg = PfbConfig::new(p, m);
            let graph = if which == 8 {
                lower::pfb_fir(b, l, cfg).unwrap()
            } else {
                lower::pfb(b, l, cfg).unwrap()
            };
            (graph, vec![Tensor::randn(&[b, l], g.u64())])
        }
        10 => {
            let nfft = *g.choose(&[16usize, 32]);
            let hop = nfft / 2;
            let l = nfft + hop * g.usize_in(0, 8);
            let b = g.usize_in(1, 2);
            (
                lower::stft(b, l, nfft, hop).unwrap(),
                vec![Tensor::randn(&[b, l], g.u64())],
            )
        }
        11 => {
            let (b, n) = (g.usize_in(1, 3), g.usize_in(1, 12));
            (
                lower::complex_mul(b, n),
                (0..4).map(|_| Tensor::randn(&[b, n], g.u64())).collect(),
            )
        }
        12 => {
            let (b, n) = (g.usize_in(1, 3), g.usize_in(1, 12));
            (
                lower::magnitude_sq(b, n),
                (0..2).map(|_| Tensor::randn(&[b, n], g.u64())).collect(),
            )
        }
        13 => {
            let mb = g.usize_in(1, 4);
            let na = g.usize_in(1, 2);
            let depth = g.usize_in(1, 4);
            let l = mb + depth * na + g.usize_in(1, 40);
            let b_taps: Vec<f32> = (0..mb).map(|_| g.normal_f32()).collect();
            let a_taps: Vec<f32> = (0..na).map(|_| 0.3 * g.normal_f32()).collect();
            let b = g.usize_in(1, 3);
            (
                lower::iir(b, l, &b_taps, &a_taps, depth).unwrap(),
                vec![Tensor::randn(&[b, l], g.u64())],
            )
        }
        14 => {
            let m = g.usize_in(1, 12);
            let l = m + g.usize_in(0, 100);
            let b = g.usize_in(1, 3);
            (
                lower::xcorr(b, l, m).unwrap(),
                vec![Tensor::randn(&[b, l], g.u64()), Tensor::randn(&[m], g.u64())],
            )
        }
        15 => {
            let nfft = *g.choose(&[8usize, 16]);
            let hop = nfft / 2;
            let l = nfft + hop * g.usize_in(0, 6);
            let b = g.usize_in(1, 2);
            let gains: Vec<f32> = (0..nfft).map(|_| g.normal_f32()).collect();
            (
                lower::fx_correlate(b, l, nfft, hop, &gains).unwrap(),
                vec![Tensor::randn(&[b, l], g.u64()), Tensor::randn(&[b, l], g.u64())],
            )
        }
        16 => {
            let c = g.usize_in(1, 4);
            let delays: Vec<usize> = (0..c).map(|_| g.usize_in(0, 3)).collect();
            let gains: Vec<f32> = (0..c).map(|_| g.normal_f32()).collect();
            let d = delays.iter().max().unwrap() + 1;
            let l = d + g.usize_in(0, 60);
            let b = g.usize_in(1, 3);
            (
                lower::beamform(b, c, l, &delays, &gains).unwrap(),
                vec![Tensor::randn(&[b, c, l], g.u64())],
            )
        }
        _ => {
            let p = *g.choose(&[4usize, 8]);
            let m = g.usize_in(2, 4);
            let l = p * (m + g.usize_in(1, 20));
            let b = g.usize_in(1, 3);
            (
                lower::spectrometer(b, l, PfbConfig::new(p, m)).unwrap(),
                vec![Tensor::randn(&[b, l], g.u64())],
            )
        }
    }
}

#[test]
fn prop_planned_executor_matches_interpreter_oracle() {
    // The planned engine restructures execution (baked constants, aliased
    // reshapes, fused elementwise chains, recycled buffers, threaded rows)
    // but keeps every kernel's accumulation order identical to the
    // interpreter's — so on the standard lowerings the outputs must be
    // bit-for-bit equal, not merely close.
    run("planned executor == interpreter (bitwise)", 40, |g: &mut Gen| {
        let (graph, inputs) = random_lowering(g);
        let interp = Interpreter::new(graph.clone()).unwrap();
        let plan = ExecPlan::compile(&graph).map_err(|e| e.to_string())?;
        plan.verify().map_err(|e| e.to_string())?;
        let want = interp.run(&inputs).map_err(|e| e.to_string())?;
        let got = plan.run(&inputs).map_err(|e| e.to_string())?;
        prop_assert!(got.len() == want.len(), "output arity");
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            prop_assert!(a.shape() == b.shape(), "output {i} shape");
            prop_assert!(
                a == b,
                "output {i} diverged, max abs diff {}",
                a.max_abs_diff(b).unwrap_or(f32::NAN)
            );
        }
        Ok(())
    });
}

#[test]
fn prop_planned_reuse_is_safe_across_repeat_runs() {
    // One Planned instance (shared plan + arena pool) over many distinct
    // inputs: recycled buffers must never leak one request's data into the
    // next — every run re-checked against the oracle.
    run("arena reuse is request-safe", 15, |g: &mut Gen| {
        let (graph, _) = random_lowering(g);
        let interp = Interpreter::new(graph.clone()).unwrap();
        let planned = Planned::new(&graph).map_err(|e| e.to_string())?;
        for _ in 0..3 {
            let inputs: Vec<Tensor> = interp
                .graph()
                .inputs
                .iter()
                .map(|(_, shape)| Tensor::randn(shape, g.u64()))
                .collect();
            let want = interp.run(&inputs).map_err(|e| e.to_string())?;
            let got = planned.run(&inputs).map_err(|e| e.to_string())?;
            for (a, b) in got.iter().zip(&want) {
                prop_assert!(a == b, "stale arena data leaked into a result");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_terminal_views_match_interpreter_bitwise() {
    // Graphs whose outputs ARE views (transpose / permute / slice as the
    // terminal node): the planned engine keeps them metadata-only and
    // gathers them at output time, so results must stay bit-identical and
    // the plan must contain no Materialize step at all.
    run("terminal view outputs == interpreter (bitwise)", 40, |g: &mut Gen| {
        let h = g.usize_in(1, 10);
        let w = g.usize_in(1, 10);
        let co = g.usize_in(1, 12);
        let mut gr = Graph::new();
        let x = gr.input(&[h, w]);
        let k = gr.constant(Tensor::randn(&[w, co], g.u64()));
        let b = gr.constant(Tensor::randn(&[co], g.u64()));
        let y = gr.push(NodeOp::FullyConnected, &[x, k, b]); // (h, co)
        let out = match g.usize_in(0, 2) {
            0 => gr.push(NodeOp::Transpose2, &[y]),
            1 => {
                let r = gr.push(NodeOp::Reshape(vec![h, co, 1]), &[y]);
                gr.push(NodeOp::Permute3([1, 0, 2]), &[r])
            }
            _ => {
                let stride = g.usize_in(1, co);
                let count = (co - 1) / stride + 1;
                gr.push(NodeOp::StridedSlice { axis: 1, stride, count }, &[y])
            }
        };
        gr.set_outputs(&[out, y]);
        let inputs = vec![Tensor::randn(&[h, w], g.u64())];
        let interp = Interpreter::new(gr.clone()).unwrap();
        let plan = ExecPlan::compile(&gr).map_err(|e| e.to_string())?;
        plan.verify().map_err(|e| e.to_string())?;
        prop_assert!(
            plan.materialize_count() == 0,
            "terminal views must stay metadata-only (h={h} w={w} co={co})"
        );
        let want = interp.run(&inputs).map_err(|e| e.to_string())?;
        let got = plan.run(&inputs).map_err(|e| e.to_string())?;
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            prop_assert!(a.shape() == b.shape(), "output {i} shape");
            prop_assert!(a == b, "output {i} diverged");
        }
        Ok(())
    });
}

#[test]
fn prop_diamond_views_share_backing_safely() {
    // One producer feeds both a strided view (terminal output) and a
    // materializing consumer: the liveness pass must keep the backing slot
    // alive until the final output gather, across arena reuse.
    run("diamond: view + materializing consumer", 25, |g: &mut Gen| {
        let n = g.usize_in(1, 12);
        let mut gr = Graph::new();
        let a = gr.input(&[n, n]);
        let b = gr.input(&[n, n]);
        let s = gr.push(NodeOp::Add, &[a, b]);
        let t = gr.push(NodeOp::Transpose2, &[s]); // strided view of s
        let u = gr.push(NodeOp::Sub, &[s, a]); // reads s's buffer directly
        gr.set_outputs(&[t, u]);
        let interp = Interpreter::new(gr.clone()).unwrap();
        let planned = Planned::new(&gr).map_err(|e| e.to_string())?;
        planned.plan().verify().map_err(|e| e.to_string())?;
        for _ in 0..3 {
            let inputs = vec![
                Tensor::randn(&[n, n], g.u64()),
                Tensor::randn(&[n, n], g.u64()),
            ];
            let want = interp.run(&inputs).map_err(|e| e.to_string())?;
            let got = planned.run(&inputs).map_err(|e| e.to_string())?;
            for (a, b) in got.iter().zip(&want) {
                prop_assert!(a == b, "view read a recycled backing slot (n={n})");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fuzzed_random_graphs_match_interpreter_bitwise() {
    // The randomized differential fuzzer, now across ALL THREE executors:
    // ~240 seeded random graphs (chains and diamonds over conv/FC/Add/Sub
    // and all four movement ops, STFT-like framing+window pipelines with
    // deliberate fusion-skip variants, and the lowering zoo's newer
    // families — complex pairs, unrolled-IIR chains, xcorr pipelines,
    // Chain-hinted scale chains with their own skip variants; coverage
    // asserted by `testing::prop`'s generator tests) must compile, pass
    // the independent static verifier, and match the interpreter oracle
    // bit-for-bit on
    //
    //   1. the planned executor (`ExecPlan::run`),
    //   2. the vaccel backend's load-time specializer
    //      (`LinearProgram::load` + `run` — the executor core the virtual
    //      accelerator serves from; always compiled, not feature-gated),
    //   3. (under `--features vaccel`) the full `VaccelEngine` device
    //      path: explicit load, bounded worker queue, typed errors,
    //
    // with the fusion pass enabled AND disabled, so a fusion rewrite (or
    // a specializer bug) can never hide behind the baseline planner.
    //
    // The PRNG seed is fixed (prop::Config::default); on failure the
    // runner prints the case seed for standalone reproduction.
    #[cfg(feature = "vaccel")]
    let vaccel = tina::runtime::VaccelEngine::with_defaults();
    #[cfg(feature = "vaccel")]
    let case_id = std::cell::Cell::new(0u64);
    run("fuzz: random graph plan == interpreter (bitwise)", 240, |g: &mut Gen| {
        let (graph, inputs) = random_graph(g);
        graph.validate().map_err(|e| format!("generator bug: {e}"))?;
        let interp = Interpreter::new(graph.clone()).unwrap();
        let want = interp.run(&inputs).map_err(|e| e.to_string())?;
        for fusion in [true, false] {
            let opts = CompileOptions {
                fusion,
                verify: true,
            };
            let plan = ExecPlan::compile_with(&graph, opts)
                .map_err(|e| format!("compile(fusion={fusion}): {e}"))?;
            plan.verify()
                .map_err(|e| format!("verify(fusion={fusion}): {e}"))?;
            let got = plan
                .run(&inputs)
                .map_err(|e| format!("run(fusion={fusion}): {e}"))?;
            prop_assert!(got.len() == want.len(), "output arity (fusion={fusion})");
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                prop_assert!(
                    a.shape() == b.shape(),
                    "output {i} shape (fusion={fusion})"
                );
                prop_assert!(
                    a == b,
                    "output {i} diverged (fusion={fusion}, fused_steps={}, \
                     eliminated_copies={}), max abs diff {}",
                    plan.fused_steps(),
                    plan.fusion_eliminated_copies(),
                    a.max_abs_diff(b).unwrap_or(f32::NAN)
                );
            }
            // executor 2: the load-time specializer dispatches the same
            // fused kernels with the same parameters — bit-for-bit equal
            let program = LinearProgram::load(&plan)
                .map_err(|e| format!("specialize(fusion={fusion}): {e}"))?;
            let lin = program
                .run(&inputs)
                .map_err(|e| format!("linear run(fusion={fusion}): {e}"))?;
            prop_assert!(lin.len() == want.len(), "linear arity (fusion={fusion})");
            for (i, (a, b)) in lin.iter().zip(&want).enumerate() {
                prop_assert!(
                    a == b,
                    "linear output {i} diverged from the interpreter (fusion={fusion})"
                );
            }
            // executor 3: the full virtual-accelerator device path
            #[cfg(feature = "vaccel")]
            {
                case_id.set(case_id.get() + 1);
                let name = format!("fuzz_{}", case_id.get());
                vaccel
                    .load(&name, &plan)
                    .map_err(|e| format!("vaccel load(fusion={fusion}): {e}"))?;
                let dev = vaccel
                    .try_execute(&name, &inputs)
                    .map_err(|e| format!("vaccel run(fusion={fusion}): {e}"))?;
                vaccel.unload(&name);
                prop_assert!(dev.len() == want.len(), "vaccel arity (fusion={fusion})");
                for (i, (a, b)) in dev.iter().zip(&want).enumerate() {
                    prop_assert!(
                        a == b,
                        "vaccel output {i} diverged from the interpreter (fusion={fusion})"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn batched_stft_plans_are_copy_free_and_fused() {
    // Regression guard for the fusion pass: at every bucket size the
    // shipped STFT lowering compiles with zero Materialize steps (none
    // movement-attributed either) and the window folded into the framing
    // conv.
    for b in [2usize, 4, 8] {
        let g = lower::stft(b, 600, 64, 32).unwrap();
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.materialize_count(), 0, "B={b}: stray copy");
        assert_eq!(plan.movement_materialize_count(), 0, "B={b}");
        assert!(plan.fused_steps() > 0, "B={b}: window must fold");
        plan.verify().unwrap();
    }
    // windowed STFT at B=1 folds too (no copy existed to eliminate)
    let plan = ExecPlan::compile(&lower::stft(1, 600, 64, 32).unwrap()).unwrap();
    assert!(plan.fused_steps() > 0);
    assert_eq!(plan.materialize_count(), 0);
}

#[test]
fn verifier_accepts_every_lowering_at_every_bucket() {
    // The static-verifier acceptance contract: every shipped lowering,
    // compiled at every bucket size with the fusion pass on AND off,
    // passes `ExecPlan::verify()` — the verifier independently re-proves
    // extents/OOB, def-use liveness, reduction-order certificates and
    // window-fold audits on the final plan.
    let cfg = PfbConfig::new(8, 4);
    let taps = dsp::fir_lowpass(16, 0.2).unwrap();
    for b in [1usize, 2, 4, 8] {
        let graphs: Vec<Graph> = vec![
            lower::ewmult(b, 16),
            lower::ewadd(b, 16),
            lower::matmul(b, 10, 4),
            lower::summation(64),
            lower::dft(b, 16),
            lower::idft(b, 16),
            lower::fir(b, 200, &taps).unwrap(),
            lower::unfold(b, 100, 8).unwrap(),
            lower::pfb_fir(b, 8 * 32, cfg).unwrap(),
            lower::pfb(b, 8 * 32, cfg).unwrap(),
            lower::stft(b, 600, 64, 32).unwrap(),
            lower::complex_mul(b, 12),
            lower::magnitude_sq(b, 12),
            lower::iir(b, 120, &[0.4, 0.3, 0.2], &[0.25, 0.1], 4).unwrap(),
            lower::xcorr(b, 100, 9).unwrap(),
            lower::fx_correlate(b, 160, 16, 8, &[0.5; 16]).unwrap(),
            lower::beamform(b, 4, 64, &[0, 3, 1, 2], &[1.0, 0.8, -0.6, 0.4]).unwrap(),
            lower::spectrometer(b, 8 * 24, cfg).unwrap(),
        ];
        for (i, g) in graphs.iter().enumerate() {
            for fusion in [true, false] {
                let opts = CompileOptions {
                    fusion,
                    verify: true,
                };
                let plan = ExecPlan::compile_with(g, opts)
                    .unwrap_or_else(|e| panic!("graph {i} B={b} fusion={fusion}: {e}"));
                plan.verify()
                    .unwrap_or_else(|e| panic!("graph {i} B={b} fusion={fusion}: {e}"));
            }
        }
    }
}

#[test]
fn bucketed_stft_rows_on_fused_plans_match_solo_with_poison() {
    // The poisoned-padding bucket equality contract, re-run against the
    // *fused* plans: for each bucket size, k real rows + poison padding
    // through a fused (copy-free, window-folded) batched plan must
    // scatter rows bit-identical to solo B=1 interpreter runs.
    let (l, nfft, hop) = (600usize, 64usize, 32usize);
    let solo = Interpreter::new(lower::stft(1, l, nfft, hop).unwrap()).unwrap();
    for bucket in [2usize, 4, 8] {
        let rows_n = bucket - 1; // real rows; one poisoned padding row
        let plan = ExecPlan::compile(&lower::stft(bucket, l, nfft, hop).unwrap()).unwrap();
        assert!(plan.fused_steps() > 0, "B={bucket}: fused plan expected");
        assert_eq!(plan.materialize_count(), 0, "B={bucket}");
        let per_row: Vec<Tensor> = (0..rows_n)
            .map(|r| Tensor::randn(&[1, l], 7000 + (bucket * 16 + r) as u64))
            .collect();
        let mut data = Vec::with_capacity(bucket * l);
        for r in &per_row {
            data.extend_from_slice(r.data());
        }
        data.resize(bucket * l, 1.0e30); // poison, not the batcher's zeros
        let batched = Tensor::new(&[bucket, l], data).unwrap();
        let mut arena = Arena::new();
        let got = plan
            .run_rows_in(&mut arena, std::slice::from_ref(&batched), rows_n)
            .unwrap();
        for (r, row_in) in per_row.iter().enumerate() {
            let want = solo.run(std::slice::from_ref(row_in)).unwrap();
            assert_eq!(got[r].len(), want.len());
            for (a, b) in got[r].iter().zip(&want) {
                assert_eq!(a.shape(), b.shape());
                assert_eq!(
                    a, b,
                    "B={bucket} row {r}: fused bucketed run diverged or padding leaked"
                );
            }
        }
    }
}

#[test]
fn prop_unrolled_iir_approaches_reference_and_stays_bitwise() {
    // Truncation-bound oracle for the unrolled-iteration IIR: with
    // ‖a‖₁ ≤ 1/2 the iteration contracts by ‖a‖₁ per unroll level, so
    // the depth-d graph's surviving prefix must sit within
    // ‖a‖₁^d · max|y − ff| (plus float slop) of the exact recurrence —
    // and the planned executor must stay bit-for-bit with the
    // interpreter regardless of depth.
    run("unrolled IIR truncation bound", 20, |g: &mut Gen| {
        let mb = g.usize_in(1, 4);
        let na = g.usize_in(1, 2);
        let depth = g.usize_in(2, 5);
        let l = mb + depth * na + g.usize_in(10, 60);
        let b_taps: Vec<f32> = (0..mb).map(|_| g.normal_f32()).collect();
        let mut a_taps: Vec<f32> = (0..na).map(|_| g.normal_f32()).collect();
        let norm: f32 = a_taps.iter().map(|v| v.abs()).sum();
        if norm > 0.5 {
            for v in &mut a_taps {
                *v *= 0.5 / norm;
            }
        }
        let b = g.usize_in(1, 3);
        let x = Tensor::randn(&[b, l], g.u64());
        let graph = lower::iir(b, l, &b_taps, &a_taps, depth).unwrap();
        let interp = Interpreter::new(graph.clone()).unwrap();
        let got = interp
            .run(std::slice::from_ref(&x))
            .map_err(|e| e.to_string())?;
        let plan = ExecPlan::compile(&graph).map_err(|e| e.to_string())?;
        plan.verify().map_err(|e| e.to_string())?;
        let planned = plan
            .run(std::slice::from_ref(&x))
            .map_err(|e| e.to_string())?;
        prop_assert!(planned[0] == got[0], "planned IIR diverged from interpreter");
        // at B = 1 the whole unrolled chain is view-composed — no copies
        if b == 1 {
            prop_assert!(
                plan.materialize_count() == 0,
                "B=1 IIR plan must be materialize-free"
            );
        }
        let exact = dsp::iir_reference(&x, &b_taps, &a_taps).unwrap();
        let ff = naive::xcorr(&x, &b_taps).unwrap(); // y⁽⁰⁾, the iteration seed
        let w0 = l - mb + 1;
        let wout = w0 - depth * na;
        let s: f32 = a_taps.iter().map(|v| v.abs()).sum();
        let e0 = exact
            .data()
            .iter()
            .zip(ff.data())
            .map(|(a, f)| (a - f).abs())
            .fold(0.0f32, f32::max);
        let bound = s.powi(depth as i32) * e0 * 1.01 + 1e-4;
        prop_assert!(got[0].shape() == [b, wout], "output shape");
        for bi in 0..b {
            for n in 0..wout {
                let gv = got[0].at(&[bi, n]);
                let ev = exact.at(&[bi, n]);
                prop_assert!(
                    (gv - ev).abs() <= bound,
                    "bi={bi} n={n}: |{gv} - {ev}| > {bound} \
                     (s={s} depth={depth} mb={mb} na={na} l={l})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_xcorr_matches_naive_reference_bitwise() {
    // xcorr vs the direct O(L·M) reference: same ascending-tap
    // accumulation order, so interpreter AND planned executor must be
    // bit-for-bit equal, not merely close.
    run("xcorr == naive O(L*M) reference (bitwise)", 30, |g: &mut Gen| {
        let m = g.usize_in(1, 16);
        let l = m + g.usize_in(0, 200);
        let b = g.usize_in(1, 3);
        let x = Tensor::randn(&[b, l], g.u64());
        let t = Tensor::randn(&[m], g.u64());
        let want = naive::xcorr(&x, t.data()).unwrap();
        let graph = lower::xcorr(b, l, m).unwrap();
        let got = Interpreter::new(graph.clone())
            .unwrap()
            .run(&[x.clone(), t.clone()])
            .map_err(|e| e.to_string())?;
        prop_assert!(got[0] == want, "interp vs naive diverged (b={b} l={l} m={m})");
        let plan = ExecPlan::compile(&graph).map_err(|e| e.to_string())?;
        plan.verify().map_err(|e| e.to_string())?;
        let planned = plan.run(&[x, t]).map_err(|e| e.to_string())?;
        prop_assert!(planned[0] == want, "plan vs naive diverged (b={b} l={l} m={m})");
        Ok(())
    });
}

#[test]
fn prop_spectrometer_single_plan_equals_staged_pipeline_bitwise() {
    // The ONE-graph spectrometer contract: the single fused copy-free
    // plan must equal a staged pipeline (PFB graph, then a separate
    // square-and-integrate graph) bit-for-bit — staging only inserts
    // exact movement, never different arithmetic.
    run("spectrometer one-plan == staged (bitwise)", 12, |g: &mut Gen| {
        let p = *g.choose(&[4usize, 8]);
        let mt = g.usize_in(2, 4);
        let l = p * (mt + g.usize_in(1, 12));
        let cfg = PfbConfig::new(p, mt);
        let b = g.usize_in(1, 3);
        let ns = l / p - mt + 1;
        let x = Tensor::randn(&[b, l], g.u64());
        let graph = lower::spectrometer(b, l, cfg).unwrap();
        let plan = ExecPlan::compile(&graph).map_err(|e| e.to_string())?;
        plan.verify().map_err(|e| e.to_string())?;
        prop_assert!(
            plan.materialize_count() == 0,
            "fused spectrometer must be copy-free (b={b} l={l} p={p} m={mt})"
        );
        let fused = plan
            .run(std::slice::from_ref(&x))
            .map_err(|e| e.to_string())?;
        // staged: lower::pfb emits (B, Ns, P) complex spectra; stage 2
        // permutes back to (B, P, Ns) and squares + integrates exactly
        // like the fused graph's tail
        let stage1 = Interpreter::new(lower::pfb(b, l, cfg).unwrap()).unwrap();
        let spectra = stage1
            .run(std::slice::from_ref(&x))
            .map_err(|e| e.to_string())?;
        let q = b * p * ns;
        let mut g2 = Graph::new();
        let re_in = g2.input(&[b, ns, p]);
        let im_in = g2.input(&[b, ns, p]);
        let rep = g2.push(NodeOp::Permute3([0, 2, 1]), &[re_in]);
        let imp = g2.push(NodeOp::Permute3([0, 2, 1]), &[im_in]);
        let sq = |gr: &mut Graph, v| {
            let a = gr.push(NodeOp::Reshape(vec![1, q, 1]), &[v]);
            let k = gr.push(NodeOp::Reshape(vec![q, 1]), &[v]);
            let bias = gr.constant(Tensor::zeros(&[q]));
            gr.push(NodeOp::DepthwiseConv1d, &[a, k, bias])
        };
        let rr = sq(&mut g2, rep);
        let ii = sq(&mut g2, imp);
        let pow = g2.push(NodeOp::Add, &[rr, ii]);
        let rows = g2.push(NodeOp::Reshape(vec![b * p, ns]), &[pow]);
        let ksum = g2.constant(Tensor::ones(&[ns, 1]));
        let b1 = g2.constant(Tensor::zeros(&[1]));
        let o = g2.push(NodeOp::FullyConnected, &[rows, ksum, b1]);
        let o = g2.push(NodeOp::Reshape(vec![b, p]), &[o]);
        g2.set_outputs(&[o]);
        let staged = Interpreter::new(g2)
            .unwrap()
            .run(&spectra)
            .map_err(|e| e.to_string())?;
        prop_assert!(
            fused[0] == staged[0],
            "one-plan spectrometer != staged pipeline (b={b} l={l} p={p} m={mt})"
        );
        Ok(())
    });
}

#[test]
fn bucketed_new_lowering_rows_match_solo_with_poison() {
    // The bucket-equality contract for every new family: at B∈{2,4,8},
    // k = B−1 real rows + one poisoned padding row through the bucketed
    // plan must scatter bit-identical to solo B=1 interpreter runs.
    // Batched inputs (declared shape grows with B) are stacked + poisoned;
    // shared inputs (xcorr's template) pass through verbatim.
    struct Case {
        name: &'static str,
        build: Box<dyn Fn(usize) -> Graph>,
    }
    let cfg = PfbConfig::new(8, 4);
    let gains: Vec<f32> = (0..16).map(|i| 0.5 + 0.05 * i as f32).collect();
    let cases: Vec<Case> = vec![
        Case {
            name: "complex_mul",
            build: Box::new(|b| lower::complex_mul(b, 12)),
        },
        Case {
            name: "magnitude_sq",
            build: Box::new(|b| lower::magnitude_sq(b, 12)),
        },
        Case {
            name: "iir",
            build: Box::new(|b| lower::iir(b, 160, &[0.4, 0.3, 0.2], &[0.25, 0.1], 4).unwrap()),
        },
        Case {
            name: "xcorr",
            build: Box::new(|b| lower::xcorr(b, 120, 9).unwrap()),
        },
        Case {
            name: "fx_correlate",
            build: Box::new(move |b| lower::fx_correlate(b, 160, 16, 8, &gains).unwrap()),
        },
        Case {
            name: "beamform",
            build: Box::new(|b| {
                lower::beamform(b, 4, 64, &[0, 3, 1, 2], &[1.0, 0.8, -0.6, 0.4]).unwrap()
            }),
        },
        Case {
            name: "spectrometer",
            build: Box::new(move |b| lower::spectrometer(b, 8 * 24, cfg).unwrap()),
        },
    ];
    for case in &cases {
        for bucket in [2usize, 4, 8] {
            let k = bucket - 1; // real rows; one poisoned padding row
            let solo_graph = (case.build)(1);
            let solo = Interpreter::new(solo_graph.clone()).unwrap();
            let bg = (case.build)(bucket);
            let plan = ExecPlan::compile(&bg).unwrap();
            plan.verify()
                .unwrap_or_else(|e| panic!("{} B={bucket}: {e}", case.name));
            let mut solo_rows: Vec<Vec<Tensor>> = vec![Vec::new(); k];
            let mut batched_inputs: Vec<Tensor> = Vec::new();
            let mut seed = 9100 + bucket as u64 * 131;
            for (i, (_, bshape)) in bg.inputs.iter().enumerate() {
                let sshape = &solo_graph.inputs[i].1;
                if bshape == sshape {
                    let t = Tensor::randn(sshape, seed);
                    seed += 1;
                    for row in solo_rows.iter_mut() {
                        row.push(t.clone());
                    }
                    batched_inputs.push(t);
                } else {
                    let row_n: usize = sshape.iter().product();
                    let mut data = Vec::with_capacity(bucket * row_n);
                    for row in solo_rows.iter_mut() {
                        let t = Tensor::randn(sshape, seed);
                        seed += 1;
                        data.extend_from_slice(t.data());
                        row.push(t);
                    }
                    data.resize(bucket * row_n, 1.0e30); // poison padding
                    batched_inputs.push(Tensor::new(bshape, data).unwrap());
                }
            }
            let mut arena = Arena::new();
            let got = plan
                .run_rows_in(&mut arena, &batched_inputs, k)
                .unwrap_or_else(|e| panic!("{} B={bucket}: {e}", case.name));
            for (r, si) in solo_rows.iter().enumerate() {
                let want = solo.run(si).unwrap();
                assert_eq!(got[r].len(), want.len(), "{} B={bucket} row {r}", case.name);
                for (a, b) in got[r].iter().zip(&want) {
                    assert_eq!(a.shape(), b.shape(), "{} B={bucket} row {r}", case.name);
                    assert_eq!(
                        a, b,
                        "{} B={bucket} row {r}: bucketed run diverged or padding leaked",
                        case.name
                    );
                }
            }
        }
    }
}

#[test]
fn prop_bucketed_batch_rows_match_solo_interpreter_bitwise() {
    // The batched-fallback contract: a plan compiled at the bucket batch
    // size B, fed k real rows plus poisoned padding (the batcher pads
    // zeros; poison is a strictly harsher test of row isolation), must
    // scatter per-row outputs that are bit-for-bit equal to solo B=1
    // interpreter runs — and the padding must never surface.
    run("bucketed batch row == solo interpreter (bitwise)", 20, |g: &mut Gen| {
        let which = g.usize_in(0, 3);
        let (l, build): (usize, Box<dyn Fn(usize) -> Graph>) = match which {
            0 => {
                let taps = dsp::fir_lowpass(g.usize_in(2, 24), 0.2).unwrap();
                let l = taps.len() + g.usize_in(1, 200);
                (l, Box::new(move |b| lower::fir(b, l, &taps).unwrap()))
            }
            1 | 2 => {
                let p = *g.choose(&[4usize, 8]);
                let m = g.usize_in(2, 5);
                let l = p * (m + g.usize_in(2, 20));
                let cfg = PfbConfig::new(p, m);
                if which == 1 {
                    (l, Box::new(move |b| lower::pfb_fir(b, l, cfg).unwrap()))
                } else {
                    (l, Box::new(move |b| lower::pfb(b, l, cfg).unwrap()))
                }
            }
            _ => {
                let nfft = *g.choose(&[16usize, 32]);
                let hop = nfft / 2;
                let l = nfft + hop * g.usize_in(0, 6);
                (l, Box::new(move |b| lower::stft(b, l, nfft, hop).unwrap()))
            }
        };
        let k = g.usize_in(1, 8); // real rows
        let bucket = k.next_power_of_two();
        let rows: Vec<Tensor> = (0..k).map(|_| Tensor::randn(&[1, l], g.u64())).collect();
        let mut data = Vec::with_capacity(bucket * l);
        for r in &rows {
            data.extend_from_slice(r.data());
        }
        data.resize(bucket * l, 1.0e30); // poison padding rows
        let batched = Tensor::new(&[bucket, l], data).unwrap();

        let plan = ExecPlan::compile(&build(bucket)).map_err(|e| e.to_string())?;
        plan.verify().map_err(|e| e.to_string())?;
        let mut arena = Arena::new();
        let got = plan
            .run_rows_in(&mut arena, std::slice::from_ref(&batched), k)
            .map_err(|e| e.to_string())?;
        prop_assert!(got.len() == k, "row arity");

        let solo = Interpreter::new(build(1)).unwrap();
        for (r, row_in) in rows.iter().enumerate() {
            let want = solo
                .run(std::slice::from_ref(row_in))
                .map_err(|e| e.to_string())?;
            prop_assert!(got[r].len() == want.len(), "row {r} output arity");
            for (i, (a, b)) in got[r].iter().zip(&want).enumerate() {
                prop_assert!(a.shape() == b.shape(), "row {r} output {i} shape");
                prop_assert!(
                    a == b,
                    "row {r} output {i}: bucketed run diverged or padding leaked \
                     (which={which} l={l} k={k} bucket={bucket})"
                );
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_fallback_batcher_buckets_round_up_and_conserve_rows() {
    // shape-bucketed keys: every enqueued row appears exactly once in
    // arrival order, each formed batch pads to the next power-of-two
    // bucket (capped at max_bucket), and padding rows are zero
    run("fallback bucket routing", 25, |g: &mut Gen| {
        let l = g.usize_in(4, 32);
        let n_rows = g.usize_in(1, 20);
        let max_bucket = *g.choose(&[2usize, 4, 8]);
        let batcher = Batcher::new(BatcherConfig {
            max_wait: std::time::Duration::from_millis(1),
            max_bucket,
        });
        let key = BatchKey::Fallback {
            op: OpKind::Fir,
            len: l,
        };
        let metrics = Arc::new(Metrics::new());
        for i in 0..n_rows {
            let row = Tensor::filled(&[1, l], (i + 1) as f32);
            batcher.enqueue(key.clone(), row, test_completion(&metrics).1);
        }
        let mut seen = Vec::new();
        while seen.len() < n_rows {
            let Some(formed) = batcher.next_batch(std::time::Duration::from_millis(100)) else {
                return Err(format!("batcher starved after {} rows", seen.len()));
            };
            let b = formed.input.shape()[0];
            prop_assert!(
                b == formed.rows.len().next_power_of_two().min(max_bucket),
                "bucket {b} for {} rows (max_bucket {max_bucket})",
                formed.rows.len()
            );
            prop_assert!(formed.input.shape()[1] == l, "row length");
            for (r, p) in formed.rows.iter().enumerate() {
                let v = formed.input.at(&[r, 0]);
                prop_assert!(v == p.input.at(&[0, 0]), "row {r} scrambled");
                seen.push(v);
            }
            for r in formed.rows.len()..b {
                prop_assert!(formed.input.at(&[r, 0]) == 0.0, "padding not zero");
            }
        }
        let want: Vec<f32> = (1..=n_rows).map(|i| i as f32).collect();
        prop_assert!(seen == want, "order {seen:?}");
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_and_orders_rows() {
    // whatever arrival pattern, every enqueued row appears exactly once,
    // in arrival order, with zero padding beyond the real rows
    run("batcher row conservation", 25, |g: &mut Gen| {
        let batch = g.usize_in(2, 8);
        let l = g.usize_in(4, 32);
        let n_rows = g.usize_in(1, 3 * batch);
        let batcher = Batcher::new(BatcherConfig {
            max_wait: std::time::Duration::from_millis(1),
            ..Default::default()
        });
        let key = BatchKey::Artifact {
            name: "test".into(),
            batch,
        };
        let metrics = Arc::new(Metrics::new());
        for i in 0..n_rows {
            let row = Tensor::filled(&[1, l], (i + 1) as f32);
            batcher.enqueue(key.clone(), row, test_completion(&metrics).1);
        }
        let mut seen = Vec::new();
        while seen.len() < n_rows {
            let Some(formed) = batcher.next_batch(std::time::Duration::from_millis(100)) else {
                return Err(format!("batcher starved after {} rows", seen.len()));
            };
            prop_assert!(formed.rows.len() <= batch, "overfull batch");
            prop_assert!(
                formed.input.shape() == [batch, l],
                "padded shape {:?}",
                formed.input.shape()
            );
            for (r, p) in formed.rows.iter().enumerate() {
                let v = formed.input.at(&[r, 0]);
                prop_assert!(v == p.input.at(&[0, 0]), "row {r} scrambled");
                seen.push(v);
            }
            // padding rows are zero
            for r in formed.rows.len()..batch {
                prop_assert!(formed.input.at(&[r, 0]) == 0.0, "padding not zero");
            }
        }
        // arrival order preserved globally (FIFO per key)
        let want: Vec<f32> = (1..=n_rows).map(|i| i as f32).collect();
        prop_assert!(seen == want, "order {seen:?}");
        Ok(())
    });
}

#[test]
fn prop_scatter_routes_rows_to_owners() {
    run("scatter_results row routing", 25, |g: &mut Gen| {
        let batch = g.usize_in(2, 8);
        let rows_n = g.usize_in(1, batch);
        let out_w = g.usize_in(1, 8);
        let metrics = Arc::new(Metrics::new());
        let mut slots = Vec::new();
        let mut rows = Vec::new();
        for _ in 0..rows_n {
            let (slot, completion) = test_completion(&metrics);
            slots.push(slot);
            rows.push(Pending {
                input: Tensor::zeros(&[1, 4]),
                completion,
                enqueued: std::time::Instant::now(),
            });
        }
        let batch_t = tina::coordinator::batcher::FormedBatch {
            key: BatchKey::Artifact {
                name: "t".into(),
                batch,
            },
            input: Tensor::zeros(&[batch, 4]),
            rows,
            adaptive: None,
        };
        // output rows tagged by row index
        let out = Tensor::new(
            &[batch, out_w],
            (0..batch).flat_map(|i| vec![i as f32; out_w]).collect::<Vec<_>>(),
        )
        .unwrap();
        scatter_results(batch_t, Ok(vec![out]));
        for (i, r) in slots.iter().enumerate() {
            let got = r.try_take().ok_or("no reply")?.map_err(|e| e.to_string())?;
            prop_assert!(
                got.outputs[0].data().iter().all(|&v| v == i as f32),
                "row {i} got wrong data"
            );
            prop_assert!(got.batched, "drain-scatter responses are batched");
        }
        prop_assert!(
            metrics.drain_completions.load(std::sync::atomic::Ordering::Relaxed)
                == rows_n as u64,
            "every row completes from the drain scatter"
        );
        Ok(())
    });
}

#[test]
fn prop_graph_shape_inference_matches_execution() {
    // for random op graphs, static shape inference == runtime shapes
    run("shape inference == runtime", 30, |g: &mut Gen| {
        let h = g.usize_in(1, 12);
        let w = g.usize_in(1, 12);
        let graph = if g.bool() {
            lower::ewmult(h, w)
        } else {
            lower::ewadd(h, w)
        };
        let shapes = graph.infer_shapes().map_err(|e| e.to_string())?;
        let it = Interpreter::new(graph.clone()).unwrap();
        let out = it
            .run(&[Tensor::randn(&[h, w], g.u64()), Tensor::randn(&[h, w], g.u64())])
            .map_err(|e| e.to_string())?;
        for (o, id) in out.iter().zip(&graph.outputs) {
            prop_assert!(
                o.shape() == shapes[id.0].as_slice(),
                "static {:?} vs runtime {:?}",
                shapes[id.0],
                o.shape()
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// substrate invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f32_in(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..g.usize_in(0, 12))
                    .map(|_| *g.choose(&['a', 'Z', '9', '"', '\\', '\n', 'µ', ' ']))
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    run("json roundtrip", 200, |g: &mut Gen| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let back = json::parse(&text).map_err(|e| e.to_string())?;
        prop_assert!(back == v, "roundtrip failed for {text}");
        Ok(())
    });
}

#[test]
fn prop_tensor_transpose_involution() {
    run("transpose2 is an involution", 50, |g: &mut Gen| {
        let r = g.usize_in(1, 20);
        let c = g.usize_in(1, 20);
        let t = Tensor::randn(&[r, c], g.u64());
        let tt = t.transpose2().unwrap().transpose2().unwrap();
        prop_assert!(t == tt, "{r}x{c}");
        Ok(())
    });
}

#[test]
fn prop_concat_slice_inverse() {
    run("slice(concat) == parts", 50, |g: &mut Gen| {
        let cols = g.usize_in(1, 8);
        let r1 = g.usize_in(1, 10);
        let r2 = g.usize_in(1, 10);
        let a = Tensor::randn(&[r1, cols], g.u64());
        let b = Tensor::randn(&[r2, cols], g.u64());
        let c = Tensor::concat(&[&a, &b], 0).unwrap();
        prop_assert!(c.slice_axis(0, 0, r1).unwrap() == a, "front");
        prop_assert!(c.slice_axis(0, r1, r1 + r2).unwrap() == b, "back");
        Ok(())
    });
}

#[test]
fn prop_bf16_quantization_error_bounded() {
    run("bf16 relative error <= 2^-8", 200, |g: &mut Gen| {
        let x = g.f32_in(-1e20, 1e20);
        let q = tina::util::bf16::quantize_bf16(x);
        if x != 0.0 && x.is_finite() {
            let rel = ((q - x) / x).abs();
            prop_assert!(rel <= tina::util::bf16::BF16_EPS, "x={x} q={q} rel={rel}");
        }
        Ok(())
    });
}
