//! Protocol-conformance suite: the binary framed wire protocol and the
//! JSON line compat mode, exercised end-to-end over real TCP connections.
//!
//! Covers the tentpole contracts of the wire layer:
//!
//! * binary f32 payloads round-trip **bit-exactly** (NaN, ±inf, -0.0,
//!   denormals) where JSON mode replies with a structured error;
//! * **pipelining**: N requests written before any reply is read, replies
//!   matched by id;
//! * **streaming sessions**: a chunked FIR signal pushed over TCP equals
//!   the one-shot library run bit-for-bit, under seeded random splits;
//! * **corruption fuzz**: seeded truncations/flips/bad-magic/oversized
//!   frames never panic the handler — every connection ends in an error
//!   reply or a clean close, and the server keeps serving afterwards;
//! * the two modes coexist on one listener (auto-detected per connection
//!   from the first byte);
//! * sub-millisecond `deadline_ms` budgets are not truncated to zero.
//!
//! The suite is artifact-free (empty registry; the planned fallback
//! executor serves everything), so it runs identically on both CI backend
//! arms.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use tina::coordinator::{
    server, wire, Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest, Precision,
    ServerConfig, ServerFrame,
};
use tina::runtime::Registry;
use tina::tensor::Tensor;

/// One in-process server over an artifact-free coordinator.  Tests must
/// drop every client stream before calling [`Harness::stop`] (the server
/// joins its connection threads, which wait for client EOF).
struct Harness {
    coord: Arc<Coordinator>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<anyhow::Result<()>>,
}

impl Harness {
    fn start(cfg: ServerConfig) -> Harness {
        let registry = Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap();
        let coord = Arc::new(
            Coordinator::new(
                registry,
                CoordinatorConfig {
                    batching: false,
                    workers: 4,
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        let stop = Arc::new(AtomicBool::new(false));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let thread = {
            let coord = Arc::clone(&coord);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || server::serve_listener_with(coord, listener, stop, cfg))
        };
        Harness {
            coord,
            addr,
            stop,
            thread,
        }
    }

    fn connect(&self) -> TcpStream {
        let s = TcpStream::connect(self.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s
    }

    fn stop(self) {
        self.stop.store(true, Ordering::Release);
        self.thread.join().unwrap().unwrap();
    }
}

/// Read one server frame off a binary-mode connection.
fn read_server_frame(r: &mut BufReader<TcpStream>) -> ServerFrame {
    let mut payload = Vec::new();
    let ft = wire::read_frame(r, &mut payload, wire::DEFAULT_MAX_FRAME)
        .unwrap()
        .expect("unexpected EOF waiting for a server frame");
    wire::decode_server_frame(ft, &payload).unwrap()
}

/// Splitmix-style seeded generator for the fuzz and split tests — no
/// external RNG crates in the offline build.
fn lcg(state: &mut u64) -> u64 {
    *state = state
        .wrapping_mul(6364136223846793005)
        .wrapping_add(1442695040888963407);
    *state
}

#[test]
fn binary_f32_payloads_roundtrip_bit_exactly_over_tcp() {
    let h = Harness::start(ServerConfig::default());
    // values JSON cannot carry (NaN, ±inf), cannot preserve (-0.0 prints
    // as -0 and parses back signless only if the parser is careful), or
    // only preserves with exact decimal round-tripping (denormals)
    let x = Tensor::new(
        &[1, 6],
        vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1.0e-40, 1.5],
    )
    .unwrap();
    let ones = Tensor::new(&[1, 6], vec![1.0; 6]).unwrap();
    // what the library itself computes for x * 1.0
    let want = h
        .coord
        .execute(OpRequest::new(OpKind::EwMult, vec![x.clone(), ones.clone()]))
        .unwrap();

    let mut stream = h.connect();
    stream
        .write_all(&wire::encode_request(
            5,
            OpKind::EwMult,
            ImplPref::Auto,
            Precision::F32,
            None,
            &[x, ones],
        ))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let ServerFrame::Response { id, outputs, .. } = read_server_frame(&mut reader) else {
        panic!("expected a response frame");
    };
    assert_eq!(id, 5);
    let got = outputs[0].data();
    let exp = want.outputs[0].data();
    assert_eq!(got.len(), exp.len());
    for (i, (a, b)) in got.iter().zip(exp).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "binary reply diverged from the library result at {i}"
        );
    }
    assert!(
        h.coord.metrics().wire_binary_frames.load(Ordering::Relaxed) >= 1,
        "binary frames must be counted"
    );
    drop(reader);
    drop(stream);
    h.stop();
}

#[test]
fn non_finite_outputs_binary_carries_json_refuses() {
    let h = Harness::start(ServerConfig::default());
    // f32::MAX + f32::MAX overflows to +inf
    let t = Tensor::new(&[2], vec![f32::MAX, f32::MAX]).unwrap();

    // binary mode: the inf comes back bit-exact
    let mut bin = h.connect();
    bin.write_all(&wire::encode_request(
        1,
        OpKind::Summation,
        ImplPref::Auto,
        Precision::F32,
        None,
        std::slice::from_ref(&t),
    ))
    .unwrap();
    let mut reader = BufReader::new(bin.try_clone().unwrap());
    let ServerFrame::Response { outputs, .. } = read_server_frame(&mut reader) else {
        panic!("expected a response frame");
    };
    assert_eq!(outputs[0].data()[0].to_bits(), f32::INFINITY.to_bits());
    drop(reader);
    drop(bin);

    // JSON mode: same op, structured error (never a bare `inf` token)
    let mut json = h.connect();
    let line = format!(
        r#"{{"id": 2, "op": "summation", "inputs": [{{"shape": [2], "data": [{m}, {m}]}}]}}"#,
        m = f32::MAX
    );
    json.write_all(line.as_bytes()).unwrap();
    json.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(json.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let doc = tina::util::json::parse(&reply).unwrap();
    assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(false));
    let err = doc.get("error").and_then(|v| v.as_str()).unwrap();
    assert!(err.contains("non-finite"), "got: {err}");
    drop(reader);
    drop(json);
    h.stop();
}

#[test]
fn pipelined_requests_are_answered_in_order_and_matched_by_id() {
    const N: u64 = 16;
    let h = Harness::start(ServerConfig::default());
    let mut stream = h.connect();
    // write every request before reading any reply
    for i in 0..N {
        let t = Tensor::new(&[4], vec![i as f32; 4]).unwrap();
        stream
            .write_all(&wire::encode_request(
                100 + i,
                OpKind::Summation,
                ImplPref::Auto,
                Precision::F32,
                None,
                &[t],
            ))
            .unwrap();
    }
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for i in 0..N {
        let ServerFrame::Response { id, outputs, .. } = read_server_frame(&mut reader) else {
            panic!("expected a response frame for request {i}");
        };
        // replies come back in frame order, so the ids sequence exactly
        assert_eq!(id, 100 + i, "reply order must match request order");
        assert_eq!(outputs[0].data(), &[4.0 * i as f32]);
    }
    drop(reader);
    drop(stream);
    h.stop();
}

#[test]
fn chunked_session_over_tcp_equals_one_shot_bitwise() {
    let h = Harness::start(ServerConfig::default());
    let total = Tensor::randn(&[1, 2000], 1234);
    let want = h
        .coord
        .execute(OpRequest::new(OpKind::Fir, vec![total.clone()]))
        .unwrap();

    let mut stream = h.connect();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream
        .write_all(&wire::encode_session_open(1, OpKind::Fir))
        .unwrap();
    let ServerFrame::SessionOpened {
        session, overlap, ..
    } = read_server_frame(&mut reader)
    else {
        panic!("expected session-opened");
    };
    assert_eq!(overlap, 63, "fir_taps - 1 under the default router config");

    // seeded random chunk splits (1..=300 samples each), including runs
    // short enough to exercise the carry-accumulate path
    let data = total.data();
    let mut state = 99u64;
    let mut got: Vec<f32> = Vec::new();
    let mut offset = 0usize;
    let mut pushes = 0u64;
    while offset < data.len() {
        let n = (1 + lcg(&mut state) % 300) as usize;
        let end = (offset + n).min(data.len());
        stream
            .write_all(&wire::encode_session_push(
                10 + pushes,
                session,
                None,
                &data[offset..end],
            ))
            .unwrap();
        let ServerFrame::SessionData {
            chunk_index,
            samples,
            ..
        } = read_server_frame(&mut reader)
        else {
            panic!("expected session-data");
        };
        assert_eq!(chunk_index, pushes);
        got.extend_from_slice(&samples);
        offset = end;
        pushes += 1;
    }

    let exp = want.outputs[0].data();
    assert_eq!(got.len(), exp.len());
    for (i, (a, b)) in got.iter().zip(exp).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "chunked session output diverged from the one-shot run at {i}"
        );
    }

    stream
        .write_all(&wire::encode_session_close(999, session))
        .unwrap();
    let ServerFrame::SessionClosed {
        chunks,
        samples_in,
        samples_out,
        ..
    } = read_server_frame(&mut reader)
    else {
        panic!("expected session-closed");
    };
    assert_eq!(chunks, pushes);
    assert_eq!(samples_in, 2000);
    assert_eq!(samples_out, got.len() as u64);
    assert_eq!(h.coord.sessions().active(), 0);
    drop(reader);
    drop(stream);
    h.stop();
}

#[test]
fn corrupted_frames_never_panic_decode() {
    // decode-level fuzz: a panic anywhere in read_frame/decode fails the
    // test; every outcome must be a typed Ok/Err
    let t = Tensor::new(&[1, 8], vec![0.5; 8]).unwrap();
    let bases: Vec<Vec<u8>> = vec![
        wire::encode_request(1, OpKind::Fir, ImplPref::Auto, Precision::F32, Some(0.9), &[t]),
        wire::encode_session_open(2, OpKind::Fir),
        wire::encode_session_push(3, 1, None, &[1.0, 2.0, 3.0]),
        wire::encode_session_close(4, 1),
        wire::encode_stats(5),
    ];
    let mut state = 0xDEADBEEFu64;
    for _ in 0..400 {
        let mut bytes = bases[(lcg(&mut state) % bases.len() as u64) as usize].clone();
        match lcg(&mut state) % 6 {
            0 => {
                // truncate at a random point
                let cut = (lcg(&mut state) % bytes.len() as u64) as usize;
                bytes.truncate(cut.max(1));
            }
            1 => {
                // flip one random byte
                let i = (lcg(&mut state) % bytes.len() as u64) as usize;
                bytes[i] ^= (1 + lcg(&mut state) % 255) as u8;
            }
            // bad magic, bad version, unknown type, huge length
            2 => bytes[0] = b'{',
            3 => bytes[2] = 99,
            4 => bytes[3] = 200,
            5 => bytes[4..8].copy_from_slice(&u32::MAX.to_le_bytes()),
            _ => unreachable!(),
        }
        let mut r = std::io::Cursor::new(&bytes[..]);
        let mut payload = Vec::new();
        // a short cap keeps the huge-length arm from allocating; every
        // branch below must return, never panic
        if let Ok(Some(ft)) = wire::read_frame(&mut r, &mut payload, 1 << 20) {
            let _ = wire::decode_client_frame(ft, &payload);
        }
    }
}

#[test]
fn corrupted_frames_over_tcp_get_an_error_or_clean_close_and_serving_survives() {
    let h = Harness::start(ServerConfig {
        max_frame: 1 << 20,
        ..Default::default()
    });
    let t = Tensor::new(&[1, 8], vec![0.25; 8]).unwrap();
    let good = wire::encode_request(9, OpKind::Fir, ImplPref::Auto, Precision::F32, None, &[t]);
    let mut state = 0xC0FFEEu64;
    for round in 0..12 {
        let mut bytes = good.clone();
        match round % 6 {
            0 => bytes.truncate(1 + (lcg(&mut state) % (bytes.len() as u64 - 1)) as usize),
            1 => {
                // keep the magic byte so the corruption lands in binary
                // mode, not the JSON fallback
                let i = 1 + (lcg(&mut state) % (bytes.len() as u64 - 1)) as usize;
                bytes[i] ^= (1 + lcg(&mut state) % 255) as u8;
            }
            // bad magic[1], bad version, unknown type, oversized
            2 => bytes[1] = 0,
            3 => bytes[2] = 42,
            4 => bytes[3] = 250,
            5 => bytes[4..8].copy_from_slice(&u32::MAX.to_le_bytes()),
            _ => unreachable!(),
        }
        let mut stream = h.connect();
        stream.write_all(&bytes).unwrap();
        // half-close: a frame truncated mid-payload must end in a clean
        // close once the server sees EOF, not a hang
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut drained = Vec::new();
        // reply bytes (an error frame) or immediate EOF — both fine; a
        // read timeout (hang) or a panic-killed server is a failure
        stream.read_to_end(&mut drained).unwrap_or_else(|e| {
            panic!("round {round}: connection neither replied nor closed: {e}")
        });
        drop(stream);
    }
    // the handler absorbed every corruption: a fresh connection still
    // gets served
    let mut stream = h.connect();
    stream.write_all(&good).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let ServerFrame::Response { id, .. } = read_server_frame(&mut reader) else {
        panic!("expected a response after the fuzz rounds");
    };
    assert_eq!(id, 9);
    drop(reader);
    drop(stream);
    h.stop();
}

#[test]
fn oversized_binary_frame_is_refused_and_counted() {
    let h = Harness::start(ServerConfig {
        max_frame: 4096,
        ..Default::default()
    });
    let mut stream = h.connect();
    // a syntactically valid header declaring a payload over the cap
    let mut header = Vec::new();
    header.extend_from_slice(&wire::MAGIC);
    header.push(wire::VERSION);
    header.push(1); // Request
    header.extend_from_slice(&(100_000u32).to_le_bytes());
    stream.write_all(&header).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let ServerFrame::Error { message, .. } = read_server_frame(&mut reader) else {
        panic!("expected an error frame");
    };
    assert!(message.contains("exceeds"), "got: {message}");
    // connection is closed after the refusal
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "no frames after the oversized refusal");
    assert_eq!(h.coord.metrics().oversized_frames.load(Ordering::Relaxed), 1);
    drop(reader);
    drop(stream);
    h.stop();
}

#[test]
fn sub_millisecond_deadline_is_not_truncated_over_binary() {
    // regression: `ms as u64` used to turn deadline_ms 0.9 into a 0 ms
    // budget that shed at admission; with fractional conversion the
    // request executes
    let h = Harness::start(ServerConfig::default());
    let t = Tensor::new(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
    let mut stream = h.connect();
    stream
        .write_all(&wire::encode_request(
            77,
            OpKind::Summation,
            ImplPref::Auto,
            Precision::F32,
            Some(0.9),
            &[t],
        ))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    match read_server_frame(&mut reader) {
        ServerFrame::Response { id, outputs, .. } => {
            assert_eq!(id, 77);
            assert_eq!(outputs[0].data(), &[10.0]);
        }
        ServerFrame::Error { message, .. } => {
            panic!("a 900 µs budget must not shed instantly: {message}")
        }
        other => panic!("unexpected frame: {other:?}"),
    }
    drop(reader);
    drop(stream);
    h.stop();
}

#[test]
fn json_and_binary_connections_coexist_on_one_listener() {
    let h = Harness::start(ServerConfig::default());

    // connection A: JSON line mode
    let mut json = h.connect();
    json.write_all(
        br#"{"id": 1, "op": "summation", "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#,
    )
    .unwrap();
    json.write_all(b"\n").unwrap();
    let mut jreader = BufReader::new(json.try_clone().unwrap());
    let mut line = String::new();
    jreader.read_line(&mut line).unwrap();
    let doc = tina::util::json::parse(&line).unwrap();
    assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(true));

    // connection B: binary framed mode, same op
    let t = Tensor::new(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
    let mut bin = h.connect();
    bin.write_all(&wire::encode_request(
        2,
        OpKind::Summation,
        ImplPref::Auto,
        Precision::F32,
        None,
        &[t],
    ))
    .unwrap();
    let mut breader = BufReader::new(bin.try_clone().unwrap());
    let ServerFrame::Response { outputs, .. } = read_server_frame(&mut breader) else {
        panic!("expected a response frame");
    };
    assert_eq!(outputs[0].data(), &[10.0]);

    // stats over binary reports both protocol counters
    bin.write_all(&wire::encode_stats(3)).unwrap();
    let ServerFrame::StatsReply { report, .. } = read_server_frame(&mut breader) else {
        panic!("expected a stats reply");
    };
    assert!(report.contains("wire_json_lines=1"), "report: {report}");
    assert!(report.contains("wire_binary_frames="), "report: {report}");

    let m = h.coord.metrics();
    assert_eq!(m.wire_json_lines.load(Ordering::Relaxed), 1);
    assert!(m.wire_binary_frames.load(Ordering::Relaxed) >= 2);
    drop(jreader);
    drop(json);
    drop(breader);
    drop(bin);
    h.stop();
}
