//! Chaos suite: deterministic fault injection against the serving stack.
//!
//! Requires the `fault-injection` feature (`cargo test --features
//! fault-injection --test chaos`).  Every scenario drives the coordinator
//! through `testing::faults` — seeded, replayable fault schedules at
//! named sites — and asserts the fault-containment contract:
//!
//! * no request ever hangs (every wait below is a bounded `wait_timeout`);
//! * every admitted request settles exactly once, with a result or an
//!   error (`completed + failed == requests`);
//! * requests a fault did not touch return bit-for-bit what the
//!   interpreter oracle returns for the same input;
//! * a panicking kernel fails only its own batch, quarantines its plan
//!   key, and the exec pool survives to serve later batches;
//! * shutdown drains within a bounded deadline even with panics and slow
//!   kernels in flight.
//!
//! The fault registry is process-global, so every test serializes on one
//! mutex and resets the registry on entry and exit (panic-safe via the
//! `Scenario` drop guard).  Run with `--test-threads=1` (the CI chaos job
//! does) to keep scenario output readable.

#![cfg(feature = "fault-injection")]

use std::sync::atomic::Ordering;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};
use tina::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, OpKind, OpRequest, PlanKey, RouterConfig,
};
#[cfg(feature = "vaccel")]
use tina::coordinator::ImplPref;
use tina::runtime::Registry;
use tina::tensor::Tensor;
use tina::testing::faults::{self, Fault, Mode};

/// Generous settle bound: far above any scenario's real latency, far
/// below the harness timeout — a wait that trips this is a hang.
const SETTLE: Duration = Duration::from_secs(30);

/// Serializes scenarios (the fault registry is process-global) and
/// resets armed rules on entry and exit, even when an assert panics.
struct Scenario(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Scenario {
    fn begin() -> Scenario {
        static LOCK: Mutex<()> = Mutex::new(());
        let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        faults::reset();
        Scenario(guard)
    }
}

impl Drop for Scenario {
    fn drop(&mut self) {
        faults::reset();
    }
}

fn empty_registry() -> Registry {
    Registry::from_manifest_text(
        std::path::PathBuf::from("/nonexistent"),
        r#"{"version": 1, "entries": []}"#,
    )
    .unwrap()
}

/// Registry with a batched fir artifact: under `--features vaccel` the
/// coordinator lowers it into the virtual accelerator's program table,
/// so the artifact arm of the batcher runs against a *real* second
/// backend and `exec.batch.artifact` faults hit live execution.
#[cfg(feature = "vaccel")]
fn fir_artifact_registry() -> Registry {
    Registry::from_manifest_text(
        std::path::PathBuf::from("/nonexistent"),
        r#"{
          "version": 1,
          "entries": [
            {"name": "fir_tina_f32_B8_L1024", "op": "fir", "impl": "tina",
             "dtype": "f32", "params": {"l": 1024, "taps": 64, "batch": 8},
             "inputs": [{"shape": [8, 1024], "dtype": "float32"}],
             "outputs": [{"shape": [8, 961], "dtype": "float32"}],
             "file": "b.hlo.txt"}
          ]
        }"#,
    )
    .unwrap()
}

/// Chaos-friendly config: batching on, `max_bucket: 1` pins every
/// bucketed plan key to `(op, [1, L])` so quarantine assertions are
/// deterministic, and a short quarantine backoff lets parole be tested.
fn chaos_config() -> CoordinatorConfig {
    CoordinatorConfig {
        batching: true,
        workers: 2,
        exec_pool_size: 2,
        admission_timeout: Duration::from_millis(500),
        drain_deadline: Duration::from_secs(2),
        batcher: BatcherConfig {
            max_bucket: 1,
            ..Default::default()
        },
        router: RouterConfig {
            quarantine_backoff: Duration::from_millis(100),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn coordinator(config: CoordinatorConfig) -> Coordinator {
    Coordinator::new(empty_registry(), config).unwrap()
}

fn fir(l: usize, seed: u64) -> OpRequest {
    OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, l], seed)])
}

/// What the interpreter oracle says a (1, L) fir request must return —
/// the bit-for-bit expectation for every untouched request.
fn oracle(c: &Coordinator, x: &Tensor) -> Vec<Tensor> {
    c.router()
        .interpreter_for_shapes(OpKind::Fir, &[vec![1, x.shape()[1]]])
        .unwrap()
        .run(std::slice::from_ref(x))
        .unwrap()
}

#[test]
fn panicking_kernel_fails_only_its_batch_quarantines_and_degrades() {
    let _s = Scenario::begin();
    let c = coordinator(chaos_config());
    faults::arm("plan.execute", Fault::Panic, Mode::Times(1));

    // the poisoned batch: its waiter errors, never hangs
    let err = c
        .submit(fir(256, 1))
        .wait_timeout(SETTLE)
        .expect("poisoned batch must settle, not hang")
        .unwrap_err();
    assert!(err.to_string().contains("quarantined"), "got: {err}");
    let m = c.metrics();
    assert_eq!(m.exec_panics.load(Ordering::Relaxed), 1);
    assert_eq!(m.quarantined_plans.load(Ordering::Relaxed), 1);
    assert!(
        c.router()
            .is_quarantined(&PlanKey::for_shapes(OpKind::Fir, &[vec![1, 256]])),
        "panicked key must be quarantined"
    );

    // same key, next request: degraded to the interpreter oracle —
    // bit-for-bit the planned result, and the exec pool survived
    let x = Tensor::randn(&[1, 256], 2);
    let resp = c
        .submit(OpRequest::new(OpKind::Fir, vec![x.clone()]))
        .wait_timeout(SETTLE)
        .expect("degraded request must settle")
        .expect("degraded request must succeed");
    assert_eq!(resp.served_by, "interp:fir");
    assert!(resp.batched);
    assert_eq!(m.degraded_requests.load(Ordering::Relaxed), 1);
    let want = oracle(&c, &x);
    assert_eq!(resp.outputs.len(), want.len());
    for (a, b) in resp.outputs.iter().zip(&want) {
        assert_eq!(a, b, "degraded output diverged from the oracle");
    }

    // an untouched key still serves on the planned path, bitwise-correct
    let y = Tensor::randn(&[1, 320], 3);
    let other = c
        .submit(OpRequest::new(OpKind::Fir, vec![y.clone()]))
        .wait_timeout(SETTLE)
        .expect("untouched request must settle")
        .unwrap();
    for (a, b) in other.outputs.iter().zip(&oracle(&c, &y)) {
        assert_eq!(a, b, "untouched output diverged from the oracle");
    }
    assert_eq!(m.degraded_requests.load(Ordering::Relaxed), 1, "no extra degrade");

    // parole: after the backoff the key recompiles and serves planned
    std::thread::sleep(Duration::from_millis(150));
    let again = c
        .submit(fir(256, 4))
        .wait_timeout(SETTLE)
        .expect("paroled request must settle")
        .unwrap();
    assert!(again.batched);
    assert_eq!(
        m.degraded_requests.load(Ordering::Relaxed),
        1,
        "paroled key must serve planned again, not degraded"
    );
}

#[test]
fn slow_batch_delays_but_settles_and_queued_rows_shed_on_expiry() {
    let _s = Scenario::begin();
    let mut config = chaos_config();
    // one exec worker: the slow batch holds it, the next batch queues
    config.exec_pool_size = 1;
    let c = coordinator(config);
    faults::arm(
        "exec.batch.fallback",
        Fault::Slow(Duration::from_millis(300)),
        Mode::Times(1),
    );

    let slow = c.submit(fir(128, 1));
    // let the slow batch reach the exec worker before queueing the next
    std::thread::sleep(Duration::from_millis(50));
    let doomed = c.submit(fir(256, 2).with_deadline(Duration::from_millis(100)));

    let slow_resp = slow
        .wait_timeout(SETTLE)
        .expect("slow batch must settle, not hang")
        .expect("slow batch must succeed after the stall");
    assert!(slow_resp.batched);
    let err = doomed
        .wait_timeout(SETTLE)
        .expect("expired row must settle")
        .unwrap_err();
    assert!(err.to_string().contains("shed"), "got: {err}");
    let m = c.metrics();
    assert_eq!(m.shed_expired_rows.load(Ordering::Relaxed), 1);
    assert_eq!(m.exec_panics.load(Ordering::Relaxed), 0);
    assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
}

#[test]
fn injected_engine_error_settles_waiters_without_quarantine() {
    let _s = Scenario::begin();
    let c = coordinator(chaos_config());
    faults::arm("exec.batch.fallback", Fault::Error, Mode::Times(1));

    let err = c
        .submit(fir(192, 1))
        .wait_timeout(SETTLE)
        .expect("errored batch must settle")
        .unwrap_err();
    assert!(err.to_string().contains("injected error"), "got: {err}");
    let m = c.metrics();
    // an engine *error* is a normal failure: no panic, no quarantine
    assert_eq!(m.exec_panics.load(Ordering::Relaxed), 0);
    assert_eq!(m.quarantined_plans.load(Ordering::Relaxed), 0);

    // the key was never poisoned: the next request serves planned
    let x = Tensor::randn(&[1, 192], 2);
    let resp = c
        .submit(OpRequest::new(OpKind::Fir, vec![x.clone()]))
        .wait_timeout(SETTLE)
        .expect("retry must settle")
        .unwrap();
    for (a, b) in resp.outputs.iter().zip(&oracle(&c, &x)) {
        assert_eq!(a, b);
    }
    assert_eq!(m.degraded_requests.load(Ordering::Relaxed), 0);
    assert_eq!(m.requests.load(Ordering::Relaxed), 2);
    assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
}

#[test]
fn exec_pool_refusal_fails_the_batch_waiters_fast() {
    let _s = Scenario::begin();
    let c = coordinator(chaos_config());
    faults::arm("exec_pool.submit", Fault::Refuse, Mode::Times(1));

    let t0 = Instant::now();
    let refused = c
        .submit(fir(128, 1))
        .wait_timeout(SETTLE)
        .expect("refused batch's waiter must settle, not hang");
    assert!(refused.is_err(), "refused batch must fail its waiters");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "refusal must fail fast, not wait out a timeout"
    );
    assert!(faults::hits("exec_pool.submit") >= 1, "site must be reached");

    // rule exhausted: the pool accepts and serves the next batch
    let ok = c
        .submit(fir(128, 2))
        .wait_timeout(SETTLE)
        .expect("post-refusal request must settle");
    assert!(ok.is_ok());
    assert_eq!(
        c.metrics().inflight_batched_requests.load(Ordering::Relaxed),
        0
    );
}

#[test]
fn gate_saturation_fault_refuses_admission_with_overload_error() {
    let _s = Scenario::begin();
    let c = coordinator(chaos_config());
    faults::arm("gate.acquire", Fault::Refuse, Mode::Times(1));

    let err = c
        .submit(fir(128, 1))
        .wait_timeout(SETTLE)
        .expect("refused admission must settle")
        .unwrap_err();
    assert!(err.to_string().contains("overloaded"), "got: {err}");
    assert_eq!(c.metrics().admission_timeouts.load(Ordering::Relaxed), 1);

    let ok = c
        .submit(fir(128, 2))
        .wait_timeout(SETTLE)
        .expect("post-fault request must settle");
    assert!(ok.is_ok());
}

#[test]
fn seeded_fault_storm_settles_every_request_exactly_once() {
    let _s = Scenario::begin();
    let c = coordinator(chaos_config());
    // ~50% of plan executions panic, ~10% of exec-pool submits are
    // refused — a deterministic storm (same seeds, same schedule)
    faults::arm(
        "plan.execute",
        Fault::Panic,
        Mode::Ratio { seed: 42, percent: 50 },
    );
    faults::arm(
        "exec_pool.submit",
        Fault::Refuse,
        Mode::Ratio { seed: 7, percent: 10 },
    );

    let lens = [128usize, 192, 256, 320];
    let inputs: Vec<Tensor> = (0..32)
        .map(|i| Tensor::randn(&[1, lens[i % lens.len()]], i as u64))
        .collect();
    let slots: Vec<_> = inputs
        .iter()
        .map(|x| c.submit(OpRequest::new(OpKind::Fir, vec![x.clone()])))
        .collect();

    let mut ok = 0u64;
    let mut failed = 0u64;
    for (x, slot) in inputs.iter().zip(slots) {
        match slot.wait_timeout(SETTLE).expect("every request must settle") {
            Ok(resp) => {
                ok += 1;
                // a request the storm did not touch must be bit-for-bit
                // the oracle result — whether it rode the planned path or
                // a quarantined key's degraded interpreter path
                for (a, b) in resp.outputs.iter().zip(&oracle(&c, x)) {
                    assert_eq!(a, b, "surviving request diverged from the oracle");
                }
            }
            Err(_) => failed += 1,
        }
    }
    assert_eq!(ok + failed, 32, "every request settles exactly once");
    let m = c.metrics();
    assert_eq!(m.requests.load(Ordering::Relaxed), 32);
    assert_eq!(
        m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed),
        32,
        "metrics must account for every settlement exactly once"
    );
    assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
    assert!(failed >= 1, "a 50% panic storm over 32 requests should fault some");
    assert!(ok >= 1, "containment should let some requests through");
    assert!(m.exec_panics.load(Ordering::Relaxed) >= 1);
    assert!(m.quarantined_plans.load(Ordering::Relaxed) >= 1);
}

#[cfg(feature = "vaccel")]
#[test]
fn artifact_batch_panic_on_vaccel_quarantines_and_degrades() {
    // the artifact-arm containment contract, against the REAL second
    // backend: a panic injected at `exec.batch.artifact` while the
    // vaccel engine serves the batch fails only that batch's waiters,
    // quarantines the artifact by name, degrades follow-up traffic to
    // the interpreter oracle, and paroles back onto vaccel afterwards
    let _s = Scenario::begin();
    let c = Coordinator::new(fir_artifact_registry(), chaos_config()).unwrap();
    assert_eq!(c.engine().backend_name(), "vaccel");
    assert!(
        c.router().artifact_arm_live(),
        "loaded vaccel programs must arm the artifact arm"
    );
    faults::arm("exec.batch.artifact", Fault::Panic, Mode::Times(1));

    // the poisoned artifact batch: its waiter errors, never hangs
    let err = c
        .submit(fir(1024, 1).with_impl(ImplPref::Tina))
        .wait_timeout(SETTLE)
        .expect("poisoned artifact batch must settle, not hang")
        .unwrap_err();
    assert!(err.to_string().contains("quarantined"), "got: {err}");
    let m = c.metrics();
    assert_eq!(m.exec_panics.load(Ordering::Relaxed), 1);
    assert!(
        c.router().is_artifact_quarantined("fir_tina_f32_B8_L1024"),
        "panicked artifact must be quarantined by name"
    );

    // while quarantined, strict artifact traffic degrades to the oracle
    let x = Tensor::randn(&[1, 1024], 2);
    let resp = c
        .submit(OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Tina))
        .wait_timeout(SETTLE)
        .expect("degraded request must settle")
        .expect("degraded request must succeed");
    assert_eq!(resp.served_by, "interp:fir");
    assert!(m.degraded_requests.load(Ordering::Relaxed) >= 1);
    for (a, b) in resp.outputs.iter().zip(&oracle(&c, &x)) {
        assert_eq!(a, b, "degraded output diverged from the oracle");
    }

    // parole: after the backoff the artifact serves again on the real
    // vaccel backend — batched, bit-for-bit the oracle result
    std::thread::sleep(Duration::from_millis(150));
    let y = Tensor::randn(&[1, 1024], 3);
    let again = c
        .submit(OpRequest::new(OpKind::Fir, vec![y.clone()]).with_impl(ImplPref::Tina))
        .wait_timeout(SETTLE)
        .expect("paroled request must settle")
        .unwrap();
    assert_eq!(again.served_by, "fir_tina_f32_B8_L1024");
    assert!(again.batched, "paroled artifact traffic rides the batcher");
    for (a, b) in again.outputs.iter().zip(&oracle(&c, &y)) {
        assert_eq!(a, b, "vaccel artifact output diverged from the oracle");
    }
    assert!(m.vaccel_batches.load(Ordering::Relaxed) >= 1);
    assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
}

#[test]
fn shutdown_under_fault_settles_all_waiters_within_drain_deadline() {
    let _s = Scenario::begin();
    let mut config = chaos_config();
    config.exec_pool_size = 1;
    config.drain_deadline = Duration::from_secs(2);
    let c = coordinator(config);
    // the in-flight batch stalls 400ms, then its plan panics — shutdown
    // must ride out both and still return within the drain deadline
    faults::arm(
        "exec.batch.fallback",
        Fault::Slow(Duration::from_millis(400)),
        Mode::Times(1),
    );
    faults::arm("plan.execute", Fault::Panic, Mode::Times(1));

    let inflight = c.submit(fir(128, 1));
    // let the stalled batch occupy the lone exec worker...
    std::thread::sleep(Duration::from_millis(50));
    // ...then pile a second batch behind it and shut down mid-traffic
    let queued = c.submit(fir(256, 2));
    std::thread::sleep(Duration::from_millis(30));

    let t0 = Instant::now();
    c.shutdown();
    let took = t0.elapsed();
    assert!(
        took < Duration::from_millis(1500),
        "shutdown must drain within the deadline, took {took:?}"
    );

    // every waiter settled: the stalled batch panicked (error), the
    // queued batch was dropped at pool close or failed by the batcher
    let a = inflight
        .wait_timeout(Duration::from_secs(1))
        .expect("in-flight waiter must be settled by shutdown");
    assert!(a.is_err(), "panicked in-flight batch must error");
    let b = queued
        .wait_timeout(Duration::from_secs(1))
        .expect("queued waiter must be settled by shutdown");
    assert!(b.is_err(), "queued batch must error at shutdown");
    let m = c.metrics();
    assert_eq!(m.exec_panics.load(Ordering::Relaxed), 1);
    assert_eq!(
        m.inflight_batched_requests.load(Ordering::Relaxed),
        0,
        "gauge must settle to zero after shutdown under fault"
    );
    assert_eq!(m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed), 2);
}
