//! End-to-end coordinator tests: full serving path over real artifacts —
//! routing, dynamic batching, pipelines, concurrency, failure injection.
//!
//! Artifact-backed tests need `make artifacts` only on the PJRT-stub
//! build: under `--features vaccel` the virtual accelerator executes the
//! specialized plans itself, so [`coordinator`] falls back to a synthetic
//! manifest and every artifact-arm test runs un-skipped.  The
//! completion-driven serving tests at the bottom drive the planned
//! fallback path and need no artifacts on either backend.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;
use tina::baselines::naive;
use tina::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest, Pipeline,
    Precision,
};
use tina::dsp::PfbConfig;
use tina::runtime::Registry;
use tina::tensor::Tensor;

fn coordinator(batching: bool) -> Option<Coordinator> {
    let config = CoordinatorConfig {
        batching,
        workers: 4,
        ..Default::default()
    };
    match Coordinator::from_dir("artifacts", config.clone()) {
        Ok(c) => Some(c),
        Err(e) => artifactless_coordinator(config, e),
    }
}

/// Mirror of the `make artifacts` sweep as manifest text: the vaccel
/// backend specializes plans from the registry metadata alone, so no
/// `.hlo.txt` files (and no artifacts directory) are needed.  Shapes and
/// names match what the artifact-backed tests pin.
#[cfg(feature = "vaccel")]
const SYNTH_MANIFEST: &str = r#"{
  "version": 1,
  "entries": [
    {"name": "ewmult_tina_f32_32x32", "op": "ewmult", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [32, 32], "dtype": "float32"},
                {"shape": [32, 32], "dtype": "float32"}],
     "outputs": [{"shape": [32, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "ewadd_tina_f32_32x32", "op": "ewadd", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [32, 32], "dtype": "float32"},
                {"shape": [32, 32], "dtype": "float32"}],
     "outputs": [{"shape": [32, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "matmul_tina_f32_32x32x32", "op": "matmul", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [32, 32], "dtype": "float32"},
                {"shape": [32, 32], "dtype": "float32"}],
     "outputs": [{"shape": [32, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_tina_f32_L1024", "op": "summation", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [1024], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_tina_f32_L4096", "op": "summation", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [4096], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_tina_f32_L16384", "op": "summation", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [16384], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_tina_f32_L65536", "op": "summation", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [65536], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_jaxref_f32_L1024", "op": "summation", "impl": "jaxref",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [1024], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_jaxref_f32_L4096", "op": "summation", "impl": "jaxref",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [4096], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_jaxref_f32_L16384", "op": "summation", "impl": "jaxref",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [16384], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "summation_jaxref_f32_L65536", "op": "summation", "impl": "jaxref",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [65536], "dtype": "float32"}],
     "outputs": [{"shape": [1], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "dft_tina_f32_B4_N64", "op": "dft", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [4, 64], "dtype": "float32"}],
     "outputs": [{"shape": [4, 64], "dtype": "float32"},
                 {"shape": [4, 64], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "idft_tina_f32_B4_N64", "op": "idft", "impl": "tina",
     "dtype": "f32", "params": {"batch": 1},
     "inputs": [{"shape": [4, 64], "dtype": "float32"},
                {"shape": [4, 64], "dtype": "float32"}],
     "outputs": [{"shape": [4, 64], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "fir_tina_f32_B1_L1024", "op": "fir", "impl": "tina",
     "dtype": "f32", "params": {"taps": 64, "batch": 1},
     "inputs": [{"shape": [1, 1024], "dtype": "float32"}],
     "outputs": [{"shape": [1, 961], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "fir_tina_f32_B1_L4096", "op": "fir", "impl": "tina",
     "dtype": "f32", "params": {"taps": 64, "batch": 1},
     "inputs": [{"shape": [1, 4096], "dtype": "float32"}],
     "outputs": [{"shape": [1, 4033], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "fir_tina_f32_B8_L4096", "op": "fir", "impl": "tina",
     "dtype": "f32", "params": {"taps": 64, "batch": 8},
     "inputs": [{"shape": [8, 4096], "dtype": "float32"}],
     "outputs": [{"shape": [8, 4033], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "unfold_tina_f32_B1_L1024", "op": "unfold", "impl": "tina",
     "dtype": "f32", "params": {"window": 32, "batch": 1},
     "inputs": [{"shape": [1, 1024], "dtype": "float32"}],
     "outputs": [{"shape": [1, 993, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "pfb_fir_tina_f32_B1_L4096", "op": "pfb_fir", "impl": "tina",
     "dtype": "f32", "params": {"branches": 32, "taps_per_branch": 8, "batch": 1},
     "inputs": [{"shape": [1, 4096], "dtype": "float32"}],
     "outputs": [{"shape": [1, 121, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "pfb_tina_f32_B1_L4096", "op": "pfb", "impl": "tina",
     "dtype": "f32", "params": {"branches": 32, "taps_per_branch": 8, "batch": 1},
     "inputs": [{"shape": [1, 4096], "dtype": "float32"}],
     "outputs": [{"shape": [1, 121, 32], "dtype": "float32"},
                 {"shape": [1, 121, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "pfb_tina_bf16_B1_L4096", "op": "pfb", "impl": "tina",
     "dtype": "bf16", "params": {"branches": 32, "taps_per_branch": 8, "batch": 1},
     "inputs": [{"shape": [1, 4096], "dtype": "float32"}],
     "outputs": [{"shape": [1, 121, 32], "dtype": "float32"},
                 {"shape": [1, 121, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "pfb_tina_f32_B1_L16384", "op": "pfb", "impl": "tina",
     "dtype": "f32", "params": {"branches": 32, "taps_per_branch": 8, "batch": 1},
     "inputs": [{"shape": [1, 16384], "dtype": "float32"}],
     "outputs": [{"shape": [1, 505, 32], "dtype": "float32"},
                 {"shape": [1, 505, 32], "dtype": "float32"}], "file": "v.hlo.txt"},
    {"name": "stft_tina_f32_B1_L4096", "op": "stft", "impl": "tina",
     "dtype": "f32", "params": {"nfft": 256, "hop": 128, "batch": 1},
     "inputs": [{"shape": [1, 4096], "dtype": "float32"}],
     "outputs": [{"shape": [1, 31, 256], "dtype": "float32"},
                 {"shape": [1, 31, 256], "dtype": "float32"}], "file": "v.hlo.txt"}
  ]
}"#;

/// Under `--features vaccel` a missing artifacts directory is no reason
/// to skip: the virtual accelerator serves the synthetic manifest.
#[cfg(feature = "vaccel")]
fn artifactless_coordinator(config: CoordinatorConfig, e: anyhow::Error) -> Option<Coordinator> {
    eprintln!("no artifacts dir ({e}); serving the synthetic manifest on the vaccel backend");
    let registry = Registry::from_manifest_text(
        std::path::PathBuf::from("/nonexistent"),
        SYNTH_MANIFEST,
    )
    .expect("synthetic manifest parses");
    Some(Coordinator::new(registry, config).expect("vaccel coordinator"))
}

/// The PJRT stub cannot execute artifacts, so without `make artifacts`
/// output the artifact-backed tests skip with a note.
#[cfg(not(feature = "vaccel"))]
fn artifactless_coordinator(_config: CoordinatorConfig, e: anyhow::Error) -> Option<Coordinator> {
    eprintln!("skipping coordinator e2e (run `make artifacts`): {e}");
    None
}

/// Artifact-free coordinator: every request takes the planned fallback
/// path, so these tests run in any environment.
fn fallback_coordinator(config: CoordinatorConfig) -> Coordinator {
    let registry = Registry::from_manifest_text(
        std::path::PathBuf::from("/nonexistent"),
        r#"{"version": 1, "entries": []}"#,
    )
    .expect("empty manifest");
    Coordinator::new(registry, config).expect("coordinator")
}

#[test]
fn serves_every_op_of_table1() {
    let Some(coord) = coordinator(false) else { return };
    let cases: Vec<(OpKind, Vec<Tensor>)> = vec![
        (OpKind::EwMult, vec![Tensor::randn(&[32, 32], 1), Tensor::randn(&[32, 32], 2)]),
        (OpKind::EwAdd, vec![Tensor::randn(&[32, 32], 3), Tensor::randn(&[32, 32], 4)]),
        (OpKind::MatMul, vec![Tensor::randn(&[32, 32], 5), Tensor::randn(&[32, 32], 6)]),
        (OpKind::Summation, vec![Tensor::randn(&[1024], 7)]),
        (OpKind::Dft, vec![Tensor::randn(&[4, 64], 8)]),
        (OpKind::Idft, vec![Tensor::randn(&[4, 64], 9), Tensor::randn(&[4, 64], 10)]),
        (OpKind::Fir, vec![Tensor::randn(&[1, 1024], 11)]),
        (OpKind::Unfold, vec![Tensor::randn(&[1, 1024], 12)]),
        (OpKind::PfbFir, vec![Tensor::randn(&[1, 4096], 13)]),
        (OpKind::Pfb, vec![Tensor::randn(&[1, 4096], 14)]),
    ];
    for (op, inputs) in cases {
        let resp = coord
            .execute(OpRequest::new(op, inputs).with_impl(ImplPref::Tina))
            .unwrap_or_else(|e| panic!("{}: {e}", op.as_str()));
        assert!(!resp.outputs.is_empty(), "{}", op.as_str());
        assert!(
            resp.served_by.starts_with(op.as_str()),
            "{} served by {}",
            op.as_str(),
            resp.served_by
        );
    }
    assert_eq!(coord.metrics().failed.load(Ordering::Relaxed), 0);
}

#[test]
fn batcher_coalesces_concurrent_requests() {
    let Some(coord) = coordinator(true) else { return };
    let coord = Arc::new(coord);
    coord.warmup(Some("fir")).unwrap();
    let taps = tina::dsp::fir_lowpass(64, 0.25).unwrap();

    let inputs: Vec<Tensor> = (0..24).map(|i| Tensor::randn(&[1, 4096], 50 + i)).collect();
    let slots: Vec<_> = inputs
        .iter()
        .map(|x| {
            coord.submit(OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Tina))
        })
        .collect();
    let mut rode_batch = 0;
    for (x, slot) in inputs.iter().zip(slots) {
        let resp = slot.wait().unwrap();
        if resp.batched {
            rode_batch += 1;
        }
        // numerics must be unaffected by batching/padding
        let want = naive::fir(x, &taps).unwrap();
        assert!(resp.outputs[0].allclose(&want, 1e-3, 1e-4));
    }
    assert!(rode_batch > 0, "no request rode a batch");
    let m = coord.metrics();
    assert!(m.batches_executed.load(Ordering::Relaxed) > 0);
    assert!(
        m.batches_executed.load(Ordering::Relaxed) < 24,
        "each request executed alone — batching ineffective"
    );
    coord.shutdown();
}

#[test]
fn pfb_pipeline_matches_fused_artifact() {
    let Some(coord) = coordinator(false) else { return };
    let x = Tensor::randn(&[1, 16384], 60);
    let fused = coord
        .execute(OpRequest::new(OpKind::Pfb, vec![x.clone()]).with_impl(ImplPref::Tina))
        .unwrap();
    let chained = Pipeline::pfb_two_stage().run(&coord, vec![x.clone()]).unwrap();
    // chain output: (rows, P) re/im; fused: (1, Ns, P) re/im
    let cfg = PfbConfig::new(32, 8);
    let ns = cfg.output_spectra(16384).unwrap();
    let re = chained[0].reshape(&[1, ns, 32]).unwrap();
    let im = chained[1].reshape(&[1, ns, 32]).unwrap();
    assert!(re.allclose(&fused.outputs[0], 2e-3, 2e-3), "re");
    assert!(im.allclose(&fused.outputs[1], 2e-3, 2e-3), "im");
}

#[test]
fn precision_routing_selects_bf16_artifacts() {
    let Some(coord) = coordinator(false) else { return };
    let x = Tensor::randn(&[1, 4096], 61);
    let resp = coord
        .execute(
            OpRequest::new(OpKind::Pfb, vec![x])
                .with_impl(ImplPref::Tina)
                .with_precision(Precision::Bf16),
        )
        .unwrap();
    assert!(resp.served_by.contains("bf16"), "served by {}", resp.served_by);
}

#[test]
fn concurrent_mixed_workload_completes() {
    let Some(coord) = coordinator(true) else { return };
    let coord = Arc::new(coord);
    let mut slots = Vec::new();
    for i in 0..60u64 {
        let req = match i % 3 {
            0 => OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 4096], i)]),
            1 => OpRequest::new(
                OpKind::MatMul,
                vec![Tensor::randn(&[64, 64], i), Tensor::randn(&[64, 64], i + 1)],
            ),
            _ => OpRequest::new(OpKind::Summation, vec![Tensor::randn(&[4096], i)]),
        };
        slots.push(coord.submit(req));
    }
    for s in slots {
        s.wait().unwrap();
    }
    let m = coord.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), 60);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    coord.shutdown();
}

#[test]
fn failure_injection_bad_requests_fail_cleanly() {
    let Some(coord) = coordinator(true) else { return };
    // arity error
    let r = coord.execute(OpRequest::new(OpKind::MatMul, vec![Tensor::zeros(&[2, 2])]));
    assert!(r.is_err());
    // contraction mismatch (caught at plan build)
    let r = coord.execute(OpRequest::new(
        OpKind::MatMul,
        vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[4, 2])],
    ));
    assert!(r.is_err());
    // PFB length not divisible by branches
    let r = coord.execute(OpRequest::new(OpKind::Pfb, vec![Tensor::zeros(&[1, 1000])]));
    assert!(r.is_err());
    // strict-tina on an unknown size
    let r = coord.execute(
        OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 12345])]).with_impl(ImplPref::Tina),
    );
    assert!(r.is_err());
    // the coordinator keeps serving afterwards
    let ok = coord.execute(OpRequest::new(OpKind::Summation, vec![Tensor::randn(&[1024], 1)]));
    assert!(ok.is_ok());
    assert!(coord.metrics().failed.load(Ordering::Relaxed) >= 4);
}

#[test]
fn stft_extension_op_serves_and_matches_naive() {
    let Some(coord) = coordinator(false) else { return };
    let x = Tensor::randn(&[1, 4096], 70);
    // artifact path
    let resp = coord
        .execute(OpRequest::new(OpKind::Stft, vec![x.clone()]).with_impl(ImplPref::Tina))
        .unwrap();
    assert_eq!(resp.served_by, "stft_tina_f32_B1_L4096");
    let (want_re, want_im) = naive::stft(&x, 256, 128).unwrap();
    assert!(resp.outputs[0].allclose(&want_re, 2e-3, 2e-2), "re");
    assert!(resp.outputs[1].allclose(&want_im, 2e-3, 2e-2), "im");
    // interpreter fallback (size outside the sweep) must agree too
    let y = Tensor::randn(&[1, 3000], 71);
    let resp = coord
        .execute(OpRequest::new(OpKind::Stft, vec![y.clone()]))
        .unwrap();
    assert_eq!(resp.served_by, "interp:stft");
    let (want_re, _) = naive::stft(&y, 256, 128).unwrap();
    assert!(resp.outputs[0].allclose(&want_re, 2e-3, 2e-2));
}

#[test]
fn warmup_compiles_requested_ops() {
    let Some(coord) = coordinator(false) else { return };
    let n = coord.warmup(Some("summation")).unwrap();
    assert_eq!(n, 8, "8 summation artifacts (4 sizes x 2 impls)");
    let stats = coord.engine().stats().unwrap();
    if coord.engine().backend_name() == "vaccel" {
        // the virtual accelerator specializes every registry entry once at
        // construction; warmup only confirms residency, so `compiles`
        // covers the whole manifest, not just the filtered op
        assert!(stats.compiles as usize >= n, "loads {} < {n}", stats.compiles);
    } else {
        assert_eq!(stats.compiles as usize, n);
    }
}

/// The three stable `served_by` labels a client may key on, pinned
/// end-to-end against the real second backend:
///
/// * artifact arm — the artifact name, executed on the virtual
///   accelerator;
/// * planned fallback — `interp:<op>` for sizes outside the sweep;
/// * quarantine degradation — `interp:<op>` again, bitwise-equal
///   outputs, with the degraded-request counter ticking.
#[cfg(feature = "vaccel")]
#[test]
fn served_by_labels_pin_plan_artifact_and_degraded_responses() {
    let coord = coordinator(false).expect("vaccel backend needs no artifacts dir");
    assert_eq!(coord.engine().backend_name(), "vaccel");
    assert!(coord.engine().capability().can_execute);

    // artifact response: served under the artifact's registry name
    let x = Tensor::randn(&[1, 1024], 90);
    let art = coord
        .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Tina))
        .unwrap();
    assert_eq!(art.served_by, "fir_tina_f32_B1_L1024");
    assert!(coord.metrics().vaccel_batches.load(Ordering::Relaxed) >= 1);

    // planned-fallback response: off-sweep size, label pinned to interp:<op>
    let plan = coord
        .execute(OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 2048], 91)]))
        .unwrap();
    assert_eq!(plan.served_by, "interp:fir");

    // degraded response: quarantining the artifact reroutes the same
    // strict request to the interpreter under the same interp:<op> label
    coord.router().quarantine_artifact("fir_tina_f32_B1_L1024", "e2e label pin");
    let deg = coord
        .execute(OpRequest::new(OpKind::Fir, vec![x]).with_impl(ImplPref::Tina))
        .unwrap();
    assert_eq!(deg.served_by, "interp:fir");
    assert_eq!(deg.outputs, art.outputs, "degradation must not change bits");
    assert!(coord.metrics().degraded_requests.load(Ordering::Relaxed) >= 1);
}

// ---------------------------------------------------------------------------
// completion-driven batched serving (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn more_concurrent_batched_requests_than_workers_all_complete() {
    // The lifted-cap regression test: a 1-worker pool with a 1-slot queue
    // serves 32 concurrently in-flight batched requests.  Under the old
    // parked-worker relay design each in-flight batched request occupied
    // a pool worker (capping concurrency at the pool size and wedging the
    // single-worker configuration); completion-driven serving finishes
    // every reply from the drain-side scatter instead.
    let coord = Arc::new(fallback_coordinator(CoordinatorConfig {
        batching: true,
        workers: 1,
        queue_capacity: 1,
        ..Default::default()
    }));
    let n = 32usize;
    let xs: Vec<Tensor> = (0..n).map(|i| Tensor::randn(&[1, 512], i as u64)).collect();
    let slots: Vec<_> = xs
        .iter()
        .map(|x| coord.submit(OpRequest::new(OpKind::Fir, vec![x.clone()])))
        .collect();
    let taps = tina::dsp::fir_lowpass(64, 0.25).unwrap();
    for (x, s) in xs.iter().zip(slots) {
        let resp = s.wait().unwrap();
        assert!(resp.batched, "fallback requests must ride the batcher");
        // numerics unaffected by coalescing across > pool-size requests
        let want = naive::fir(x, &taps).unwrap();
        assert!(resp.outputs[0].allclose(&want, 1e-3, 1e-4));
    }
    let m = coord.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), n as u64);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    // zero parked-worker relays: every batched reply was completed by a
    // drain-side batch execution thread
    assert_eq!(
        m.drain_completions.load(Ordering::Relaxed),
        m.batched_fallback_requests.load(Ordering::Relaxed),
        "drain_completions must equal batched_fallback_requests"
    );
    assert_eq!(m.batched_fallback_requests.load(Ordering::Relaxed), n as u64);
    assert_eq!(
        m.inflight_batched_requests.load(Ordering::Relaxed),
        0,
        "in-flight gauge must return to zero"
    );
    coord.shutdown();
}

#[test]
fn enqueue_timestamp_survives_the_pending_path() {
    // The latency-metric regression test: `t0` is captured at submit and
    // carried through the batcher's `Pending`, so a request that waits
    // out the full flush deadline must report a latency of at least that
    // deadline — not just its (sub-millisecond) execution time.
    let max_wait = Duration::from_millis(40);
    let coord = fallback_coordinator(CoordinatorConfig {
        batching: true,
        workers: 2,
        batcher: BatcherConfig {
            max_wait,
            max_bucket: 8,
        },
        ..Default::default()
    });
    // a lone request on a cold key waits the full static deadline (no
    // arrival-rate estimate exists yet, so adaptive sizing is inactive)
    let resp = coord
        .execute(OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 256], 7)]))
        .unwrap();
    assert!(resp.batched);
    let h = coord
        .metrics()
        .latency_of("fir")
        .expect("latency histogram recorded");
    assert_eq!(h.count(), 1);
    assert!(
        h.max_ns() >= max_wait.as_nanos() as u64 * 3 / 4,
        "recorded latency {}ns must cover the {}ms queue wait — t0 lost?",
        h.max_ns(),
        max_wait.as_millis()
    );
    coord.shutdown();
}

#[test]
fn happy_path_traffic_leaves_fault_containment_counters_at_zero() {
    // the fault-containment layer must be invisible to healthy traffic:
    // no panics contained, nothing quarantined or degraded, no rows
    // shed, no admissions refused
    let coord = Arc::new(fallback_coordinator(CoordinatorConfig {
        batching: true,
        workers: 2,
        ..Default::default()
    }));
    let slots: Vec<_> = (0..16)
        .map(|i| {
            let x = Tensor::randn(&[1, 384], i as u64);
            coord.submit(
                OpRequest::new(OpKind::Fir, vec![x]).with_deadline(Duration::from_secs(60)),
            )
        })
        .collect();
    for s in slots {
        assert!(s.wait().is_ok());
    }
    let m = coord.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), 16);
    assert_eq!(m.exec_panics.load(Ordering::Relaxed), 0);
    assert_eq!(m.quarantined_plans.load(Ordering::Relaxed), 0);
    assert_eq!(m.degraded_requests.load(Ordering::Relaxed), 0);
    assert_eq!(m.shed_expired_rows.load(Ordering::Relaxed), 0);
    assert_eq!(m.admission_timeouts.load(Ordering::Relaxed), 0);
    let report = m.report();
    for key in [
        "exec_panics=0",
        "quarantined_plans=0",
        "degraded_requests=0",
        "shed_expired_rows=0",
        "admission_timeouts=0",
    ] {
        assert!(report.contains(key), "report missing {key}: {report}");
    }
    coord.shutdown();
}

#[test]
fn expired_deadline_sheds_at_admission_end_to_end() {
    // deadline-aware admission without the fault-injection feature: a
    // request whose budget already lapsed is shed before routing
    let coord = fallback_coordinator(CoordinatorConfig {
        batching: true,
        workers: 2,
        ..Default::default()
    });
    let err = coord
        .execute(
            OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 256], 1)])
                .with_deadline(Duration::ZERO),
        )
        .unwrap_err();
    assert!(err.to_string().contains("shed"), "got: {err}");
    assert_eq!(coord.metrics().shed_expired_rows.load(Ordering::Relaxed), 1);
    // the coordinator keeps serving deadline-free traffic afterwards
    let ok = coord.execute(OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 256], 2)]));
    assert!(ok.is_ok());
    coord.shutdown();
}

#[test]
fn adaptive_bucket_metrics_surface_under_traffic() {
    // bursty fallback traffic must leave the adaptive gauges populated:
    // every formed fallback batch stamps its effective cap/wait
    let coord = Arc::new(fallback_coordinator(CoordinatorConfig {
        batching: true,
        workers: 2,
        ..Default::default()
    }));
    let slots: Vec<_> = (0..8)
        .map(|i| {
            let x = Tensor::randn(&[1, 256], i as u64);
            coord.submit(OpRequest::new(OpKind::Fir, vec![x]))
        })
        .collect();
    for s in slots {
        s.wait().unwrap();
    }
    let m = coord.metrics();
    let cap = m.adaptive_bucket_cap.load(Ordering::Relaxed);
    assert!(
        (1..=8).contains(&cap),
        "adaptive cap gauge must hold the last decision, got {cap}"
    );
    let report = m.report();
    assert!(report.contains("adaptive_bucket_cap="), "report: {report}");
    assert!(report.contains("drain_completions="), "report: {report}");
    coord.shutdown();
}
