//! End-to-end coordinator tests: full serving path over real artifacts —
//! routing, dynamic batching, pipelines, concurrency, failure injection.
//!
//! Skips (with a note) when `make artifacts` has not run.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use tina::baselines::naive;
use tina::coordinator::{
    Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest, Pipeline, Precision,
};
use tina::dsp::PfbConfig;
use tina::tensor::Tensor;

fn coordinator(batching: bool) -> Option<Coordinator> {
    match Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig {
            batching,
            workers: 4,
            ..Default::default()
        },
    ) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping coordinator e2e (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn serves_every_op_of_table1() {
    let Some(coord) = coordinator(false) else { return };
    let cases: Vec<(OpKind, Vec<Tensor>)> = vec![
        (OpKind::EwMult, vec![Tensor::randn(&[32, 32], 1), Tensor::randn(&[32, 32], 2)]),
        (OpKind::EwAdd, vec![Tensor::randn(&[32, 32], 3), Tensor::randn(&[32, 32], 4)]),
        (OpKind::MatMul, vec![Tensor::randn(&[32, 32], 5), Tensor::randn(&[32, 32], 6)]),
        (OpKind::Summation, vec![Tensor::randn(&[1024], 7)]),
        (OpKind::Dft, vec![Tensor::randn(&[4, 64], 8)]),
        (OpKind::Idft, vec![Tensor::randn(&[4, 64], 9), Tensor::randn(&[4, 64], 10)]),
        (OpKind::Fir, vec![Tensor::randn(&[1, 1024], 11)]),
        (OpKind::Unfold, vec![Tensor::randn(&[1, 1024], 12)]),
        (OpKind::PfbFir, vec![Tensor::randn(&[1, 4096], 13)]),
        (OpKind::Pfb, vec![Tensor::randn(&[1, 4096], 14)]),
    ];
    for (op, inputs) in cases {
        let resp = coord
            .execute(OpRequest::new(op, inputs).with_impl(ImplPref::Tina))
            .unwrap_or_else(|e| panic!("{}: {e}", op.as_str()));
        assert!(!resp.outputs.is_empty(), "{}", op.as_str());
        assert!(
            resp.served_by.starts_with(op.as_str()),
            "{} served by {}",
            op.as_str(),
            resp.served_by
        );
    }
    assert_eq!(coord.metrics().failed.load(Ordering::Relaxed), 0);
}

#[test]
fn batcher_coalesces_concurrent_requests() {
    let Some(coord) = coordinator(true) else { return };
    let coord = Arc::new(coord);
    coord.warmup(Some("fir")).unwrap();
    let taps = tina::dsp::fir_lowpass(64, 0.25).unwrap();

    let inputs: Vec<Tensor> = (0..24).map(|i| Tensor::randn(&[1, 4096], 50 + i)).collect();
    let slots: Vec<_> = inputs
        .iter()
        .map(|x| {
            coord.submit(OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Tina))
        })
        .collect();
    let mut rode_batch = 0;
    for (x, slot) in inputs.iter().zip(slots) {
        let resp = slot.wait().unwrap();
        if resp.batched {
            rode_batch += 1;
        }
        // numerics must be unaffected by batching/padding
        let want = naive::fir(x, &taps).unwrap();
        assert!(resp.outputs[0].allclose(&want, 1e-3, 1e-4));
    }
    assert!(rode_batch > 0, "no request rode a batch");
    let m = coord.metrics();
    assert!(m.batches_executed.load(Ordering::Relaxed) > 0);
    assert!(
        m.batches_executed.load(Ordering::Relaxed) < 24,
        "each request executed alone — batching ineffective"
    );
    coord.shutdown();
}

#[test]
fn pfb_pipeline_matches_fused_artifact() {
    let Some(coord) = coordinator(false) else { return };
    let x = Tensor::randn(&[1, 16384], 60);
    let fused = coord
        .execute(OpRequest::new(OpKind::Pfb, vec![x.clone()]).with_impl(ImplPref::Tina))
        .unwrap();
    let chained = Pipeline::pfb_two_stage().run(&coord, vec![x.clone()]).unwrap();
    // chain output: (rows, P) re/im; fused: (1, Ns, P) re/im
    let cfg = PfbConfig::new(32, 8);
    let ns = cfg.output_spectra(16384).unwrap();
    let re = chained[0].reshape(&[1, ns, 32]).unwrap();
    let im = chained[1].reshape(&[1, ns, 32]).unwrap();
    assert!(re.allclose(&fused.outputs[0], 2e-3, 2e-3), "re");
    assert!(im.allclose(&fused.outputs[1], 2e-3, 2e-3), "im");
}

#[test]
fn precision_routing_selects_bf16_artifacts() {
    let Some(coord) = coordinator(false) else { return };
    let x = Tensor::randn(&[1, 4096], 61);
    let resp = coord
        .execute(
            OpRequest::new(OpKind::Pfb, vec![x])
                .with_impl(ImplPref::Tina)
                .with_precision(Precision::Bf16),
        )
        .unwrap();
    assert!(resp.served_by.contains("bf16"), "served by {}", resp.served_by);
}

#[test]
fn concurrent_mixed_workload_completes() {
    let Some(coord) = coordinator(true) else { return };
    let coord = Arc::new(coord);
    let mut slots = Vec::new();
    for i in 0..60u64 {
        let req = match i % 3 {
            0 => OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 4096], i)]),
            1 => OpRequest::new(
                OpKind::MatMul,
                vec![Tensor::randn(&[64, 64], i), Tensor::randn(&[64, 64], i + 1)],
            ),
            _ => OpRequest::new(OpKind::Summation, vec![Tensor::randn(&[4096], i)]),
        };
        slots.push(coord.submit(req));
    }
    for s in slots {
        s.wait().unwrap();
    }
    let m = coord.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), 60);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    coord.shutdown();
}

#[test]
fn failure_injection_bad_requests_fail_cleanly() {
    let Some(coord) = coordinator(true) else { return };
    // arity error
    let r = coord.execute(OpRequest::new(OpKind::MatMul, vec![Tensor::zeros(&[2, 2])]));
    assert!(r.is_err());
    // contraction mismatch (caught at plan build)
    let r = coord.execute(OpRequest::new(
        OpKind::MatMul,
        vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[4, 2])],
    ));
    assert!(r.is_err());
    // PFB length not divisible by branches
    let r = coord.execute(OpRequest::new(OpKind::Pfb, vec![Tensor::zeros(&[1, 1000])]));
    assert!(r.is_err());
    // strict-tina on an unknown size
    let r = coord.execute(
        OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 12345])]).with_impl(ImplPref::Tina),
    );
    assert!(r.is_err());
    // the coordinator keeps serving afterwards
    let ok = coord.execute(OpRequest::new(OpKind::Summation, vec![Tensor::randn(&[1024], 1)]));
    assert!(ok.is_ok());
    assert!(coord.metrics().failed.load(Ordering::Relaxed) >= 4);
}

#[test]
fn stft_extension_op_serves_and_matches_naive() {
    let Some(coord) = coordinator(false) else { return };
    let x = Tensor::randn(&[1, 4096], 70);
    // artifact path
    let resp = coord
        .execute(OpRequest::new(OpKind::Stft, vec![x.clone()]).with_impl(ImplPref::Tina))
        .unwrap();
    assert_eq!(resp.served_by, "stft_tina_f32_B1_L4096");
    let (want_re, want_im) = naive::stft(&x, 256, 128).unwrap();
    assert!(resp.outputs[0].allclose(&want_re, 2e-3, 2e-2), "re");
    assert!(resp.outputs[1].allclose(&want_im, 2e-3, 2e-2), "im");
    // interpreter fallback (size outside the sweep) must agree too
    let y = Tensor::randn(&[1, 3000], 71);
    let resp = coord
        .execute(OpRequest::new(OpKind::Stft, vec![y.clone()]))
        .unwrap();
    assert_eq!(resp.served_by, "interp:stft");
    let (want_re, _) = naive::stft(&y, 256, 128).unwrap();
    assert!(resp.outputs[0].allclose(&want_re, 2e-3, 2e-2));
}

#[test]
fn warmup_compiles_requested_ops() {
    let Some(coord) = coordinator(false) else { return };
    let n = coord.warmup(Some("summation")).unwrap();
    assert_eq!(n, 8, "8 summation artifacts (4 sizes x 2 impls)");
    let stats = coord.engine().stats().unwrap();
    assert_eq!(stats.compiles as usize, n);
}
