//! Integration tests over the full artifact path: PJRT execution vs the
//! pure-rust interpreter vs the CPU baselines.
//!
//! These need `make artifacts` to have run; they skip (with a note) when
//! the artifact directory is missing so plain `cargo test` stays green in
//! a fresh checkout.

use tina::baselines::{naive, optimized};
use tina::coordinator::{ImplPref, OpKind, OpRequest, Router, RouterConfig, Target};
use tina::dsp::PfbConfig;
use tina::runtime::{Engine, Registry};
use tina::tensor::{ComplexTensor, Tensor};

fn engine() -> Option<Engine> {
    match Engine::from_dir("artifacts") {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping integration test (run `make artifacts`): {e}");
            None
        }
    }
}

macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => return,
        }
    };
}

#[test]
fn manifest_is_complete_and_files_exist() {
    let Some(engine) = engine() else { return };
    let reg = engine.registry();
    assert!(reg.len() >= 80, "expected full sweep, got {}", reg.len());
    reg.check_files().expect("artifact files present");
    // every op of the paper's Table 1 evaluation is covered
    for op in ["ewmult", "ewadd", "matmul", "summation", "dft", "idft", "fir", "unfold", "pfb_fir", "pfb"] {
        assert!(
            !reg.find(op, "tina", "f32").is_empty(),
            "missing tina artifacts for {op}"
        );
        assert!(
            !reg.find(op, "jaxref", "f32").is_empty(),
            "missing jaxref artifacts for {op}"
        );
    }
    // bf16 variants exist for the PFB use case (Fig 3)
    assert!(!reg.find("pfb", "tina", "bf16").is_empty());
}

#[test]
fn ewmult_artifact_matches_baselines() {
    let engine = require_engine!();
    let a = Tensor::randn(&[64, 64], 10);
    let b = Tensor::randn(&[64, 64], 11);
    let got = engine
        .execute("ewmult_tina_f32_N64", &[a.clone(), b.clone()])
        .unwrap();
    let want = naive::ewmult(&a, &b).unwrap();
    assert!(got[0].allclose(&want, 1e-5, 1e-5));
    let opt = optimized::ewmult(&a, &b).unwrap();
    assert!(got[0].allclose(&opt, 1e-5, 1e-5));
}

#[test]
fn matmul_artifact_matches_naive() {
    let engine = require_engine!();
    for n in [32usize, 256] {
        let a = Tensor::randn(&[n, n], 12);
        let b = Tensor::randn(&[n, n], 13);
        let got = engine
            .execute(&format!("matmul_tina_f32_N{n}"), &[a.clone(), b.clone()])
            .unwrap();
        let want = naive::matmul(&a, &b).unwrap();
        assert!(got[0].allclose(&want, 1e-3, 1e-3), "N={n}");
    }
}

#[test]
fn summation_artifact_matches() {
    let engine = require_engine!();
    let x = Tensor::randn(&[16384], 14);
    let got = engine.execute("summation_tina_f32_L16384", &[x.clone()]).unwrap();
    let want = tina::tensor::sum(&x);
    assert!(
        (got[0].data()[0] - want).abs() <= 1e-2 * want.abs().max(1.0),
        "{} vs {want}",
        got[0].data()[0]
    );
}

#[test]
fn dft_artifact_matches_fft() {
    let engine = require_engine!();
    let x = Tensor::randn(&[4, 256], 15);
    let got = engine.execute("dft_tina_f32_B4_N256", &[x.clone()]).unwrap();
    let want = tina::dsp::fft_radix2(&ComplexTensor::from_real(x)).unwrap();
    assert!(got[0].allclose(&want.re, 5e-3, 5e-2), "re");
    assert!(got[1].allclose(&want.im, 5e-3, 5e-2), "im");
}

#[test]
fn dft_then_idft_roundtrips_through_artifacts() {
    let engine = require_engine!();
    let x = Tensor::randn(&[4, 128], 16);
    let spec = engine.execute("dft_tina_f32_B4_N128", &[x.clone()]).unwrap();
    let back = engine
        .execute("idft_tina_f32_B4_N128", &[spec[0].clone(), spec[1].clone()])
        .unwrap();
    assert!(back[0].allclose(&x, 1e-3, 1e-3), "re roundtrip");
    assert!(
        back[1].allclose(&Tensor::zeros(&[4, 128]), 1e-3, 1e-3),
        "im roundtrip"
    );
}

#[test]
fn fir_artifact_matches_baselines_all_sizes() {
    let engine = require_engine!();
    let taps = tina::dsp::fir_lowpass(64, 0.25).unwrap();
    for l in [1024usize, 4096, 16384, 65536] {
        let x = Tensor::randn(&[1, l], 17);
        let got = engine
            .execute(&format!("fir_tina_f32_B1_L{l}"), &[x.clone()])
            .unwrap();
        let want = naive::fir(&x, &taps).unwrap();
        assert!(got[0].allclose(&want, 1e-3, 1e-4), "L={l}");
    }
}

#[test]
fn unfold_artifact_is_exact() {
    let engine = require_engine!();
    let x = Tensor::randn(&[1, 4096], 18);
    let got = engine.execute("unfold_tina_f32_B1_L4096", &[x.clone()]).unwrap();
    let want = naive::unfold(&x, 32).unwrap();
    // unfolding moves data without arithmetic: bitwise equal
    assert_eq!(got[0], want);
}

#[test]
fn pfb_artifacts_match_reference() {
    let engine = require_engine!();
    let cfg = PfbConfig::new(32, 8);
    let x = Tensor::randn(&[1, 16384], 19);
    let got = engine.execute("pfb_fir_tina_f32_B1_L16384", &[x.clone()]).unwrap();
    let want = naive::pfb_fir(&x, cfg).unwrap();
    assert!(got[0].allclose(&want, 1e-3, 1e-4));

    let got = engine.execute("pfb_tina_f32_B1_L16384", &[x.clone()]).unwrap();
    let want = naive::pfb(&x, cfg).unwrap();
    assert!(got[0].allclose(&want.re, 2e-3, 2e-3), "re");
    assert!(got[1].allclose(&want.im, 2e-3, 2e-3), "im");
}

#[test]
fn bf16_artifact_close_to_f32() {
    let engine = require_engine!();
    let x = Tensor::randn(&[1, 4096], 20);
    let f32_out = engine.execute("pfb_fir_tina_f32_B1_L4096", &[x.clone()]).unwrap();
    let b16_out = engine.execute("pfb_fir_tina_bf16_B1_L4096", &[x.clone()]).unwrap();
    // bf16 carries ~2^-8 relative error through the bank
    assert!(b16_out[0].allclose(&f32_out[0], 0.15, 0.05));
    // but must NOT be identical (proves it actually computed in bf16)
    assert!(f32_out[0].max_abs_diff(&b16_out[0]).unwrap() > 0.0);
}

#[test]
fn jaxref_and_tina_artifacts_agree() {
    let engine = require_engine!();
    let x = Tensor::randn(&[1, 4096], 21);
    for op in ["fir", "unfold", "pfb_fir"] {
        let t = engine
            .execute(&format!("{op}_tina_f32_B1_L4096"), &[x.clone()])
            .unwrap();
        let j = engine
            .execute(&format!("{op}_jaxref_f32_B1_L4096"), &[x.clone()])
            .unwrap();
        for (a, b) in t.iter().zip(&j) {
            assert!(a.allclose(b, 1e-3, 1e-4), "{op} tina vs jaxref");
        }
    }
}

#[test]
fn batched_artifact_rows_are_independent() {
    let engine = require_engine!();
    // run the B8 artifact with 8 distinct rows; each row must equal the
    // B1 artifact run on that row
    let rows: Vec<Tensor> = (0..8).map(|i| Tensor::randn(&[1, 4096], 30 + i)).collect();
    let mut stacked = Vec::with_capacity(8 * 4096);
    for r in &rows {
        stacked.extend_from_slice(r.data());
    }
    let batch = Tensor::new(&[8, 4096], stacked).unwrap();
    let got = engine.execute("fir_tina_f32_B8_L4096", &[batch]).unwrap();
    for (i, r) in rows.iter().enumerate() {
        let single = engine.execute("fir_tina_f32_B1_L4096", &[r.clone()]).unwrap();
        let row = got[0].slice_axis(0, i, i + 1).unwrap();
        assert!(row.allclose(&single[0], 1e-5, 1e-5), "row {i}");
    }
}

#[test]
fn engine_rejects_bad_inputs() {
    let engine = require_engine!();
    // wrong arity
    assert!(engine.execute("fir_tina_f32_B1_L1024", &[]).is_err());
    // wrong shape
    let bad = Tensor::zeros(&[1, 999]);
    assert!(engine.execute("fir_tina_f32_B1_L1024", &[bad]).is_err());
    // unknown artifact
    assert!(engine.execute("nope", &[Tensor::zeros(&[1])]).is_err());
}

#[test]
fn executable_cache_reuses_compilations() {
    let engine = require_engine!();
    let x = Tensor::randn(&[1, 1024], 22);
    engine.execute("fir_tina_f32_B1_L1024", &[x.clone()]).unwrap();
    engine.execute("fir_tina_f32_B1_L1024", &[x.clone()]).unwrap();
    engine.execute("fir_tina_f32_B1_L1024", &[x]).unwrap();
    let stats = engine.stats();
    assert_eq!(stats.compiles, 1, "one compile");
    assert_eq!(stats.executions, 3, "three executions");
}

#[test]
fn router_targets_resolve_and_execute_via_interpreter_consistently() {
    let Some(engine) = engine() else { return };
    let registry: Registry = engine.registry().clone();
    let router = Router::new(registry, RouterConfig::default());
    // a size outside the sweep must fall back to interp and still be right
    let x = Tensor::randn(&[1, 2048], 23);
    let req = OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Auto);
    match router.route(&req).unwrap() {
        Target::Interp { key } => {
            let it = router.interpreter(&key, &req).unwrap();
            let got = it.run(&[x.clone()]).unwrap();
            let taps = tina::dsp::fir_lowpass(64, 0.25).unwrap();
            let want = naive::fir(&x, &taps).unwrap();
            assert!(got[0].allclose(&want, 1e-4, 1e-5));
        }
        t => panic!("expected interp fallback, got {t:?}"),
    }
}
