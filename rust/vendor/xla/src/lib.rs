//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build environment has no crates.io access and no libxla shared
//! objects, so the real bindings cannot be linked.  This crate keeps the
//! exact API surface `tina::runtime` compiles against:
//!
//! * [`PjRtClient::cpu`] succeeds (so the coordinator can come up and serve
//!   interpreter/planned-executor fallback traffic with an empty registry);
//! * every compile/execute entry point returns a descriptive [`Error`], so
//!   artifact-dependent tests and benches skip exactly as they do in a
//!   checkout where `make artifacts` has not run.
//!
//! Swapping in the real bindings is a one-line Cargo.toml change; no call
//! site changes.

use std::fmt;

/// Error type mirroring xla-rs's error enum shape (a message is enough for
/// the stub; `tina` only ever formats it).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (offline xla stub; link the real xla crate to execute artifacts)"
    ))
}

/// Element types the engine requests for literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Parsed HLO module (stub: parsing always fails, there is no parser).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal (stub: never actually constructed with data).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable(&format!("creating literal of shape {shape:?}")))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("reading literal data"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("decomposing tuple literal"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching device buffer"))
    }
}

/// Compiled executable handle (stub: never produced).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    // The type parameters mirror the real bindings' signatures (callers use
    // turbofish); they are intentionally unused here.
    #[allow(clippy::extra_unused_type_parameters)]
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }

    #[allow(clippy::extra_unused_type_parameters)]
    pub fn execute_b<T>(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing (buffers)"))
    }
}

/// PJRT client.  Construction succeeds so hosts that only need the
/// fallback execution paths (no artifacts) still come up.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu (offline xla stub; artifact execution disabled)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable(&format!("uploading buffer of shape {shape:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_comes_up_but_cannot_compile() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let proto = HloModuleProto::from_text_file("/nonexistent.hlo.txt");
        assert!(proto.is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto { _private: () });
        assert!(client.compile(&comp).is_err());
    }

    #[test]
    fn literal_paths_error_cleanly() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[]).is_err());
        assert!(PjRtBuffer { _private: () }.to_literal_sync().is_err());
    }
}
