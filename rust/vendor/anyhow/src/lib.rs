//! Offline stand-in for the `anyhow` crate, implementing exactly the API
//! surface this repository uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! The build environment has no crates.io access, so the real crate cannot
//! be fetched; this path dependency keeps the dependency surface identical
//! for when the genuine crate becomes available (same names, same call
//! sites, drop-in swap in Cargo.toml).
//!
//! Semantics mirror anyhow where they matter here:
//!
//! * `Display` prints the outermost message only;
//! * alternate `Display` (`{:#}`) prints the whole context chain joined by
//!   `": "` (what `tina`'s CLI error reporting relies on);
//! * `Debug` prints the message plus a `Caused by:` list, so
//!   `unwrap()`/`expect()` failures stay diagnosable;
//! * any `std::error::Error + Send + Sync + 'static` converts via `From`
//!   (which is what makes `?` work on io/json/etc. errors), and the
//!   source chain is captured at conversion time.

use std::fmt;

/// A dynamic error with a chain of context messages.
///
/// `chain[0]` is the outermost (most recently attached) message; later
/// entries are the causes, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message (used by [`Context`]).
    pub fn push_context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what lets the blanket `From` below coexist with the reflexive
// `From<Error> for Error` (same trick as the real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Wrap the error with an outer message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily-built outer message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outermost_only_alternate_chains() {
        let e: Error = io_err().into();
        let e = e.push_context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_cause(), "gone");
        let n: Option<u32> = None;
        assert!(n.with_context(|| "missing").is_err());
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x = {x} too big");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x = 12 too big");
        let msg = String::from("plain");
        assert_eq!(format!("{}", anyhow!(msg)), "plain");
    }
}
